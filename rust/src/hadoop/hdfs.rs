//! HDFS-like block store — the baseline's storage layer (paper §2):
//! "GFS and HDFS divide the data into blocks that are scattered across
//! processors ... as usually configured Sector processes a 1 TB file
//! using 64 chunks, each of which is a file, while HDFS process the
//! same data using 8,192 chunks, each of which is a block."
//!
//! Key contrasts to Sector kept faithful here: central NameNode
//! metadata (not P2P), block (not file) granularity, write-pipeline
//! replication, rack-aware placement.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::rng::Pcg64;

pub type DataNodeId = u32;
pub type BlockId = u64;

/// Metadata for one file: ordered block list.
#[derive(Clone, Debug, Default)]
pub struct HdfsFileMeta {
    pub blocks: Vec<BlockId>,
    pub size_bytes: u64,
}

/// Metadata for one block.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub id: BlockId,
    pub len: u64,
    pub replicas: Vec<DataNodeId>,
}

/// The central NameNode + in-memory DataNodes.
pub struct Hdfs {
    pub block_bytes: u64,
    pub replication: usize,
    /// node -> rack (placement spreads replicas across racks).
    pub node_rack: Vec<usize>,
    files: Mutex<HashMap<String, HdfsFileMeta>>,
    blocks: Mutex<HashMap<BlockId, BlockMeta>>,
    /// DataNode block storage.
    data: Mutex<HashMap<(DataNodeId, BlockId), Vec<u8>>>,
    next_block: Mutex<BlockId>,
    rng: Mutex<Pcg64>,
}

impl Hdfs {
    pub fn new(block_bytes: u64, replication: usize, node_rack: Vec<usize>, seed: u64) -> Self {
        assert!(block_bytes > 0 && replication >= 1 && !node_rack.is_empty());
        assert!(replication <= node_rack.len());
        Self {
            block_bytes,
            replication,
            node_rack,
            files: Mutex::new(HashMap::new()),
            blocks: Mutex::new(HashMap::new()),
            data: Mutex::new(HashMap::new()),
            next_block: Mutex::new(0),
            rng: Mutex::new(Pcg64::new(seed)),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.node_rack.len()
    }

    /// HDFS default placement: first replica on the writer's node, the
    /// second on a different rack, the third on the second's rack.
    fn place(&self, writer: DataNodeId) -> Vec<DataNodeId> {
        let n = self.n_nodes();
        let mut rng = self.rng.lock().unwrap();
        let mut chosen = vec![writer];
        let writer_rack = self.node_rack[writer as usize];
        if self.replication >= 2 {
            let off_rack: Vec<DataNodeId> = (0..n as DataNodeId)
                .filter(|&i| self.node_rack[i as usize] != writer_rack && i != writer)
                .collect();
            let pool: Vec<DataNodeId> = if off_rack.is_empty() {
                (0..n as DataNodeId).filter(|&i| i != writer).collect()
            } else {
                off_rack
            };
            if !pool.is_empty() {
                chosen.push(pool[rng.gen_range(pool.len() as u64) as usize]);
            }
        }
        while chosen.len() < self.replication {
            let pick = rng.gen_range(n as u64) as DataNodeId;
            if !chosen.contains(&pick) {
                chosen.push(pick);
            }
        }
        chosen
    }

    /// Write a file from `writer`, splitting into blocks with pipelined
    /// replication. Rejects duplicates (HDFS files are immutable).
    pub fn put(&self, writer: DataNodeId, name: &str, bytes: &[u8]) -> Result<(), String> {
        {
            let files = self.files.lock().unwrap();
            if files.contains_key(name) {
                return Err(format!("file exists: {name}"));
            }
        }
        let mut meta = HdfsFileMeta {
            blocks: Vec::new(),
            size_bytes: bytes.len() as u64,
        };
        for chunk in bytes.chunks(self.block_bytes as usize) {
            let id = {
                let mut nb = self.next_block.lock().unwrap();
                *nb += 1;
                *nb
            };
            let replicas = self.place(writer);
            {
                let mut data = self.data.lock().unwrap();
                for &node in &replicas {
                    data.insert((node, id), chunk.to_vec());
                }
            }
            self.blocks.lock().unwrap().insert(
                id,
                BlockMeta {
                    id,
                    len: chunk.len() as u64,
                    replicas,
                },
            );
            meta.blocks.push(id);
        }
        self.files.lock().unwrap().insert(name.to_string(), meta);
        Ok(())
    }

    pub fn stat(&self, name: &str) -> Option<HdfsFileMeta> {
        self.files.lock().unwrap().get(name).cloned()
    }

    pub fn block_meta(&self, id: BlockId) -> Option<BlockMeta> {
        self.blocks.lock().unwrap().get(&id).cloned()
    }

    /// Read a whole file (concatenating blocks from any replica).
    pub fn get(&self, name: &str) -> Result<Vec<u8>, String> {
        let meta = self
            .stat(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        let blocks = self.blocks.lock().unwrap();
        let data = self.data.lock().unwrap();
        let mut out = Vec::with_capacity(meta.size_bytes as usize);
        for id in &meta.blocks {
            let bm = blocks.get(id).ok_or_else(|| format!("missing block {id}"))?;
            let src = bm
                .replicas
                .first()
                .ok_or_else(|| format!("block {id} has no replicas"))?;
            let bytes = data
                .get(&(*src, *id))
                .ok_or_else(|| format!("replica of block {id} missing on node {src}"))?;
            out.extend_from_slice(bytes);
        }
        Ok(out)
    }

    /// Read one block (for map-task locality).
    pub fn read_block(&self, id: BlockId, prefer: DataNodeId) -> Result<(Vec<u8>, bool), String> {
        let bm = self
            .block_meta(id)
            .ok_or_else(|| format!("no such block {id}"))?;
        let local = bm.replicas.contains(&prefer);
        let src = if local {
            prefer
        } else {
            *bm.replicas.first().ok_or("block has no replicas")?
        };
        let data = self.data.lock().unwrap();
        Ok((
            data.get(&(src, id))
                .ok_or_else(|| format!("replica missing on {src}"))?
                .clone(),
            local,
        ))
    }

    pub fn list(&self) -> Vec<String> {
        let mut names: Vec<String> = self.files.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Blocks-per-node histogram (placement tests).
    pub fn blocks_per_node(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_nodes()];
        for bm in self.blocks.lock().unwrap().values() {
            for &r in &bm.replicas {
                counts[r as usize] += 1;
            }
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs(nodes: usize, block: u64, repl: usize) -> Hdfs {
        // two racks, split evenly
        let racks: Vec<usize> = (0..nodes).map(|i| i * 2 / nodes).collect();
        Hdfs::new(block, repl, racks, 42)
    }

    #[test]
    fn put_get_roundtrip_multi_block() {
        let h = fs(4, 10, 2);
        let payload: Vec<u8> = (0..35u8).collect();
        h.put(0, "f.dat", &payload).unwrap();
        assert_eq!(h.get("f.dat").unwrap(), payload);
        let meta = h.stat("f.dat").unwrap();
        assert_eq!(meta.blocks.len(), 4, "35 bytes / 10-byte blocks = 4");
        assert_eq!(meta.size_bytes, 35);
        assert!(h.put(0, "f.dat", &payload).is_err(), "immutable files");
        assert!(h.get("missing").is_err());
    }

    #[test]
    fn replication_spreads_across_racks() {
        let h = fs(6, 100, 2);
        h.put(0, "f.dat", &[1u8; 1000]).unwrap();
        let meta = h.stat("f.dat").unwrap();
        for id in meta.blocks {
            let bm = h.block_meta(id).unwrap();
            assert_eq!(bm.replicas.len(), 2);
            assert_eq!(bm.replicas[0], 0, "first replica on the writer");
            let r0 = h.node_rack[bm.replicas[0] as usize];
            let r1 = h.node_rack[bm.replicas[1] as usize];
            assert_ne!(r0, r1, "second replica off-rack");
        }
    }

    #[test]
    fn block_granularity_contrast_with_sector() {
        // The paper's §2 numbers: 1 TB = 8192 x 128 MB blocks vs 64 files.
        let h = fs(8, 128, 3);
        h.put(2, "tera.dat", &vec![0u8; 1024]).unwrap();
        assert_eq!(h.stat("tera.dat").unwrap().blocks.len(), 8);
        let counts = h.blocks_per_node();
        assert_eq!(counts.iter().sum::<usize>(), 24, "8 blocks x 3 replicas");
    }

    #[test]
    fn read_block_reports_locality() {
        let h = fs(4, 10, 1);
        h.put(1, "f.dat", &[7u8; 10]).unwrap();
        let id = h.stat("f.dat").unwrap().blocks[0];
        let (bytes, local) = h.read_block(id, 1).unwrap();
        assert_eq!(bytes.len(), 10);
        assert!(local, "replica 0 lands on the writer");
        let other = h.read_block(id, 2).unwrap();
        assert!(!other.1);
    }
}

//! HDFS-like block store — the baseline's storage layer (paper §2):
//! "GFS and HDFS divide the data into blocks that are scattered across
//! processors ... as usually configured Sector processes a 1 TB file
//! using 64 chunks, each of which is a file, while HDFS process the
//! same data using 8,192 chunks, each of which is a block."
//!
//! Key contrasts to Sector kept faithful here: central NameNode
//! metadata (not P2P), block (not file) granularity, write-pipeline
//! replication, rack-aware placement.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::util::rng::Pcg64;

pub type DataNodeId = u32;
pub type BlockId = u64;

/// Metadata for one file: ordered block list.
#[derive(Clone, Debug, Default)]
pub struct HdfsFileMeta {
    pub blocks: Vec<BlockId>,
    pub size_bytes: u64,
}

/// Metadata for one block.
#[derive(Clone, Debug)]
pub struct BlockMeta {
    pub id: BlockId,
    pub len: u64,
    pub replicas: Vec<DataNodeId>,
}

/// The central NameNode + in-memory DataNodes.
pub struct Hdfs {
    pub block_bytes: u64,
    pub replication: usize,
    /// node -> rack (placement spreads replicas across racks).
    pub node_rack: Vec<usize>,
    files: Mutex<HashMap<String, HdfsFileMeta>>,
    blocks: Mutex<HashMap<BlockId, BlockMeta>>,
    /// DataNode block storage.
    data: Mutex<HashMap<(DataNodeId, BlockId), Vec<u8>>>,
    next_block: Mutex<BlockId>,
    rng: Mutex<Pcg64>,
}

impl Hdfs {
    pub fn new(block_bytes: u64, replication: usize, node_rack: Vec<usize>, seed: u64) -> Self {
        assert!(block_bytes > 0 && replication >= 1 && !node_rack.is_empty());
        assert!(replication <= node_rack.len());
        Self {
            block_bytes,
            replication,
            node_rack,
            files: Mutex::new(HashMap::new()),
            blocks: Mutex::new(HashMap::new()),
            data: Mutex::new(HashMap::new()),
            next_block: Mutex::new(0),
            rng: Mutex::new(Pcg64::new(seed)),
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.node_rack.len()
    }

    /// HDFS default placement: first replica on the writer's node, the
    /// second on a different rack, the third on the second's rack.
    fn place(&self, writer: DataNodeId) -> Vec<DataNodeId> {
        let n = self.n_nodes();
        let mut rng = self.rng.lock().unwrap();
        let mut chosen = vec![writer];
        let writer_rack = self.node_rack[writer as usize];
        if self.replication >= 2 {
            let off_rack: Vec<DataNodeId> = (0..n as DataNodeId)
                .filter(|&i| self.node_rack[i as usize] != writer_rack && i != writer)
                .collect();
            let pool: Vec<DataNodeId> = if off_rack.is_empty() {
                (0..n as DataNodeId).filter(|&i| i != writer).collect()
            } else {
                off_rack
            };
            if !pool.is_empty() {
                chosen.push(pool[rng.gen_range(pool.len() as u64) as usize]);
            }
        }
        while chosen.len() < self.replication {
            let pick = rng.gen_range(n as u64) as DataNodeId;
            if !chosen.contains(&pick) {
                chosen.push(pick);
            }
        }
        chosen
    }

    /// Write a file from `writer`, splitting into blocks with pipelined
    /// replication. Rejects duplicates (HDFS files are immutable).
    pub fn put(&self, writer: DataNodeId, name: &str, bytes: &[u8]) -> Result<(), String> {
        {
            let files = self.files.lock().unwrap();
            if files.contains_key(name) {
                return Err(format!("file exists: {name}"));
            }
        }
        let mut meta = HdfsFileMeta {
            blocks: Vec::new(),
            size_bytes: bytes.len() as u64,
        };
        for chunk in bytes.chunks(self.block_bytes as usize) {
            let id = {
                let mut nb = self.next_block.lock().unwrap();
                *nb += 1;
                *nb
            };
            let replicas = self.place(writer);
            {
                let mut data = self.data.lock().unwrap();
                for &node in &replicas {
                    data.insert((node, id), chunk.to_vec());
                }
            }
            self.blocks.lock().unwrap().insert(
                id,
                BlockMeta {
                    id,
                    len: chunk.len() as u64,
                    replicas,
                },
            );
            meta.blocks.push(id);
        }
        self.files.lock().unwrap().insert(name.to_string(), meta);
        Ok(())
    }

    pub fn stat(&self, name: &str) -> Option<HdfsFileMeta> {
        self.files.lock().unwrap().get(name).cloned()
    }

    pub fn block_meta(&self, id: BlockId) -> Option<BlockMeta> {
        self.blocks.lock().unwrap().get(&id).cloned()
    }

    /// Read a whole file (concatenating blocks from any replica).
    pub fn get(&self, name: &str) -> Result<Vec<u8>, String> {
        let meta = self
            .stat(name)
            .ok_or_else(|| format!("no such file: {name}"))?;
        let blocks = self.blocks.lock().unwrap();
        let data = self.data.lock().unwrap();
        let mut out = Vec::with_capacity(meta.size_bytes as usize);
        for id in &meta.blocks {
            let bm = blocks.get(id).ok_or_else(|| format!("missing block {id}"))?;
            let src = bm
                .replicas
                .first()
                .ok_or_else(|| format!("block {id} has no replicas"))?;
            let bytes = data
                .get(&(*src, *id))
                .ok_or_else(|| format!("replica of block {id} missing on node {src}"))?;
            out.extend_from_slice(bytes);
        }
        Ok(out)
    }

    /// Read one block (for map-task locality).
    pub fn read_block(&self, id: BlockId, prefer: DataNodeId) -> Result<(Vec<u8>, bool), String> {
        let bm = self
            .block_meta(id)
            .ok_or_else(|| format!("no such block {id}"))?;
        let local = bm.replicas.contains(&prefer);
        let src = if local {
            prefer
        } else {
            *bm.replicas.first().ok_or("block has no replicas")?
        };
        let data = self.data.lock().unwrap();
        Ok((
            data.get(&(src, id))
                .ok_or_else(|| format!("replica missing on {src}"))?
                .clone(),
            local,
        ))
    }

    pub fn list(&self) -> Vec<String> {
        let mut names: Vec<String> = self.files.lock().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Blocks-per-node histogram (placement tests).
    pub fn blocks_per_node(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.n_nodes()];
        for bm in self.blocks.lock().unwrap().values() {
            for &r in &bm.replicas {
                counts[r as usize] += 1;
            }
        }
        counts
    }
}

/// Scenario-scale block placement — the NameNode's placement policy
/// (first replica on the writer, second off-rack, third on the
/// second's rack) lifted out of the byte-level [`Hdfs`] store so the
/// event-driven baseline engine (`hadoop::engine`, DESIGN.md §12) can
/// place thousands of simulated blocks without materializing bytes,
/// and re-replicate them when a DataNode dies.  Deterministic: all
/// randomness flows from the seed.
#[derive(Clone, Debug)]
pub struct Placement {
    pub replication: usize,
    node_rack: Vec<usize>,
    /// block -> replica holders, first entry = the writer's local copy.
    replicas: Vec<Vec<u32>>,
    /// block -> writer (home) node.
    pub home: Vec<u32>,
    rng: Pcg64,
}

/// What a NameNode re-replication pass produced.  The proposed copies
/// are NOT yet replicas: the engine starts a transfer per entry and
/// calls [`Placement::add_replica`] only when it lands — a block whose
/// rescue copy is still in flight when its last holder dies is lost,
/// exactly like a real under-replicated HDFS block.
#[derive(Clone, Debug, Default)]
pub struct ReReplication {
    /// (block, copy source, proposed new holder) transfers to start.
    pub moved: Vec<(usize, u32, u32)>,
    /// Blocks whose every replica sat on dead nodes — the data is gone.
    pub lost: Vec<usize>,
}

impl Placement {
    /// Place `blocks_per_node` blocks written by every node.  Block ids
    /// are dense: node `h` wrote blocks `h*blocks_per_node ..`.
    pub fn build(
        node_rack: &[usize],
        blocks_per_node: usize,
        replication: usize,
        seed: u64,
    ) -> Placement {
        assert!(replication >= 1 && !node_rack.is_empty());
        let mut p = Placement {
            replication: replication.min(node_rack.len()),
            node_rack: node_rack.to_vec(),
            replicas: Vec::with_capacity(node_rack.len() * blocks_per_node),
            home: Vec::with_capacity(node_rack.len() * blocks_per_node),
            rng: Pcg64::new(seed ^ 0x4ad0_0b10),
        };
        for writer in 0..node_rack.len() as u32 {
            for _ in 0..blocks_per_node {
                let r = p.place(writer);
                p.home.push(writer);
                p.replicas.push(r);
            }
        }
        p
    }

    pub fn blocks(&self) -> usize {
        self.replicas.len()
    }

    pub fn replicas_of(&self, block: usize) -> &[u32] {
        &self.replicas[block]
    }

    /// HDFS default placement: first replica on the writer, second on
    /// a different rack, further replicas on the second's rack when it
    /// has room, anywhere distinct otherwise.
    fn place(&mut self, writer: u32) -> Vec<u32> {
        let n = self.node_rack.len();
        let mut chosen = vec![writer];
        let writer_rack = self.node_rack[writer as usize];
        if self.replication >= 2 {
            let off_rack: Vec<u32> = (0..n as u32)
                .filter(|&i| self.node_rack[i as usize] != writer_rack && i != writer)
                .collect();
            let pool: Vec<u32> = if off_rack.is_empty() {
                (0..n as u32).filter(|&i| i != writer).collect()
            } else {
                off_rack
            };
            if !pool.is_empty() {
                chosen.push(pool[self.rng.gen_range(pool.len() as u64) as usize]);
            }
        }
        while chosen.len() < self.replication {
            let second_rack = chosen.get(1).map(|&s| self.node_rack[s as usize]);
            let mut pool: Vec<u32> = (0..n as u32)
                .filter(|&i| {
                    !chosen.contains(&i)
                        && second_rack
                            .map(|r| self.node_rack[i as usize] == r)
                            .unwrap_or(true)
                })
                .collect();
            if pool.is_empty() {
                pool = (0..n as u32).filter(|&i| !chosen.contains(&i)).collect();
            }
            if pool.is_empty() {
                break;
            }
            chosen.push(pool[self.rng.gen_range(pool.len() as u64) as usize]);
        }
        chosen
    }

    /// A DataNode died: drop every copy it held (and any copy on other
    /// already-dead nodes) and propose a rescue transfer per
    /// under-replicated block from a surviving holder, preferring a
    /// target in a rack no surviving replica occupies.  Proposals
    /// become replicas via [`Self::add_replica`] when their transfers
    /// land.
    pub fn re_replicate(&mut self, dead_node: u32, dead: &[bool]) -> ReReplication {
        let mut out = ReReplication::default();
        for b in 0..self.replicas.len() {
            if !self.replicas[b].contains(&dead_node) {
                continue;
            }
            self.replicas[b].retain(|&r| !dead[r as usize]);
            if self.replicas[b].is_empty() {
                out.lost.push(b);
                continue;
            }
            if self.replicas[b].len() >= self.replication {
                continue;
            }
            if let Some((src, dst)) = self.propose_copy(b, dead) {
                out.moved.push((b, src, dst));
            }
        }
        out
    }

    /// Pick a (source holder, new target) pair restoring block `b`'s
    /// replica count: source = any live holder, target = a live
    /// non-holder off every surviving replica's rack when possible.
    /// `None` when no live holder or no eligible target exists.
    pub fn propose_copy(&mut self, b: usize, dead: &[bool]) -> Option<(u32, u32)> {
        let n = self.node_rack.len();
        let &src = self.replicas[b].iter().find(|&&r| !dead[r as usize])?;
        let used_racks: Vec<usize> = self.replicas[b]
            .iter()
            .filter(|&&r| !dead[r as usize])
            .map(|&r| self.node_rack[r as usize])
            .collect();
        let mut pool: Vec<u32> = (0..n as u32)
            .filter(|&x| {
                !dead[x as usize]
                    && !self.replicas[b].contains(&x)
                    && !used_racks.contains(&self.node_rack[x as usize])
            })
            .collect();
        if pool.is_empty() {
            pool = (0..n as u32)
                .filter(|&x| !dead[x as usize] && !self.replicas[b].contains(&x))
                .collect();
        }
        if pool.is_empty() {
            return None;
        }
        let dst = pool[self.rng.gen_range(pool.len() as u64) as usize];
        Some((src, dst))
    }

    /// A rescue transfer landed: the target now holds block `b`.
    pub fn add_replica(&mut self, b: usize, node: u32) {
        if !self.replicas[b].contains(&node) {
            self.replicas[b].push(node);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fs(nodes: usize, block: u64, repl: usize) -> Hdfs {
        // two racks, split evenly
        let racks: Vec<usize> = (0..nodes).map(|i| i * 2 / nodes).collect();
        Hdfs::new(block, repl, racks, 42)
    }

    #[test]
    fn put_get_roundtrip_multi_block() {
        let h = fs(4, 10, 2);
        let payload: Vec<u8> = (0..35u8).collect();
        h.put(0, "f.dat", &payload).unwrap();
        assert_eq!(h.get("f.dat").unwrap(), payload);
        let meta = h.stat("f.dat").unwrap();
        assert_eq!(meta.blocks.len(), 4, "35 bytes / 10-byte blocks = 4");
        assert_eq!(meta.size_bytes, 35);
        assert!(h.put(0, "f.dat", &payload).is_err(), "immutable files");
        assert!(h.get("missing").is_err());
    }

    #[test]
    fn replication_spreads_across_racks() {
        let h = fs(6, 100, 2);
        h.put(0, "f.dat", &[1u8; 1000]).unwrap();
        let meta = h.stat("f.dat").unwrap();
        for id in meta.blocks {
            let bm = h.block_meta(id).unwrap();
            assert_eq!(bm.replicas.len(), 2);
            assert_eq!(bm.replicas[0], 0, "first replica on the writer");
            let r0 = h.node_rack[bm.replicas[0] as usize];
            let r1 = h.node_rack[bm.replicas[1] as usize];
            assert_ne!(r0, r1, "second replica off-rack");
        }
    }

    #[test]
    fn block_granularity_contrast_with_sector() {
        // The paper's §2 numbers: 1 TB = 8192 x 128 MB blocks vs 64 files.
        let h = fs(8, 128, 3);
        h.put(2, "tera.dat", &vec![0u8; 1024]).unwrap();
        assert_eq!(h.stat("tera.dat").unwrap().blocks.len(), 8);
        let counts = h.blocks_per_node();
        assert_eq!(counts.iter().sum::<usize>(), 24, "8 blocks x 3 replicas");
    }

    // ------------------------------------------- scenario-scale placement

    /// Two racks of three nodes each.
    fn racks2x3() -> Vec<usize> {
        vec![0, 0, 0, 1, 1, 1]
    }

    #[test]
    fn placement_is_rack_aware_and_write_local() {
        let p = Placement::build(&racks2x3(), 4, 2, 7);
        assert_eq!(p.blocks(), 24);
        for b in 0..p.blocks() {
            let r = p.replicas_of(b);
            assert_eq!(r.len(), 2);
            assert_eq!(r[0], p.home[b], "first replica on the writer");
            assert_ne!(
                racks2x3()[r[0] as usize],
                racks2x3()[r[1] as usize],
                "second replica off-rack (block {b}: {r:?})"
            );
        }
        // Deterministic: same seed, same placement.
        let q = Placement::build(&racks2x3(), 4, 2, 7);
        for b in 0..p.blocks() {
            assert_eq!(p.replicas_of(b), q.replicas_of(b));
        }
    }

    #[test]
    fn placement_third_replica_prefers_seconds_rack() {
        let p = Placement::build(&racks2x3(), 8, 3, 11);
        let mut on_seconds_rack = 0;
        for b in 0..p.blocks() {
            let r = p.replicas_of(b);
            assert_eq!(r.len(), 3);
            let racks = racks2x3();
            if racks[r[2] as usize] == racks[r[1] as usize] {
                on_seconds_rack += 1;
            }
        }
        assert_eq!(
            on_seconds_rack,
            p.blocks(),
            "with room in the second's rack, the third lands there"
        );
    }

    #[test]
    fn re_replication_restores_count_off_dead_node() {
        let mut p = Placement::build(&racks2x3(), 4, 2, 13);
        let mut dead = vec![false; 6];
        dead[0] = true;
        let rr = p.re_replicate(0, &dead);
        assert!(rr.lost.is_empty(), "a single death loses nothing at R=2");
        assert!(!rr.moved.is_empty(), "node 0 held copies that must move");
        for &(b, src, dst) in &rr.moved {
            assert!(!dead[src as usize] && !dead[dst as usize]);
            let r = p.replicas_of(b);
            assert_eq!(r.len(), 1, "a proposal is not yet a replica");
            assert!(!r.contains(&0), "dead node dropped from block {b}");
            // The transfer lands: now the count is restored and the
            // pair stays rack-diverse.
            p.add_replica(b, dst);
            let r = p.replicas_of(b);
            assert_eq!(r.len(), 2, "count restored for block {b}");
            assert_ne!(racks2x3()[r[0] as usize], racks2x3()[r[1] as usize]);
        }
        // Blocks untouched by the death keep their placement.
        for b in 0..p.blocks() {
            assert!(!p.replicas_of(b).is_empty());
        }
        // add_replica is idempotent.
        let (b, _, dst) = rr.moved[0];
        p.add_replica(b, dst);
        assert_eq!(p.replicas_of(b).len(), 2);
    }

    #[test]
    fn re_replication_reports_lost_blocks() {
        let mut p = Placement::build(&racks2x3(), 2, 2, 17);
        // Kill nodes until some block's whole replica set is gone:
        // killing an entire rack guarantees it (every pair is split
        // across the two racks, so kill one rack + one partner).
        let mut dead = vec![false; 6];
        for node in [0u32, 1, 2, 3] {
            dead[node as usize] = true;
        }
        let mut lost = Vec::new();
        for node in [0u32, 1, 2, 3] {
            lost.extend(p.re_replicate(node, &dead).lost);
        }
        // Survivors are 4 and 5 (rack 1): any block whose pair lived
        // entirely on {0,1,2,3} is lost; blocks with a copy on 4/5
        // survive with a restored count capped by live-rack choices.
        for b in lost {
            assert!(
                p.replicas_of(b).is_empty(),
                "lost block {b} must have no live replica"
            );
        }
    }

    #[test]
    fn read_block_reports_locality() {
        let h = fs(4, 10, 1);
        h.put(1, "f.dat", &[7u8; 10]).unwrap();
        let id = h.stat("f.dat").unwrap().blocks[0];
        let (bytes, local) = h.read_block(id, 1).unwrap();
        assert_eq!(bytes.len(), 10);
        assert!(local, "replica 0 lands on the writer");
        let other = h.read_block(id, 2).unwrap();
        assert!(!other.1);
    }
}

//! Paper-scale Hadoop 0.16 simulation — the baseline columns of
//! Tables 1–2.
//!
//! Structure follows the real engine (`mapreduce.rs`): block-granular
//! map tasks → spill → shuffle (HTTP over TCP, 5 parallel fetchers,
//! 2008-era 64 KB socket buffers) → merge → reduce → output write.
//! Mechanisms:
//!
//!   * all disk I/O through the Java stream stack at `io_efficiency`
//!     (checksums, serialization, JVM — the paper §6.3 measured 440 Mb/s
//!     HDFS writes vs 1.1 Gb/s for Sphere on identical disks);
//!   * per-task JVM startup (Hadoop 0.16 forked a JVM per task);
//!   * merge passes double when the partition exceeds memory
//!     (io.sort.mb-era multi-round merges) and halve their I/O when the
//!     page cache can hold the intermediate data;
//!   * shuffle fetches ride TCP: window-limited per stream on long-RTT
//!     paths (transport::tcp), aggregated over parallel copies;
//!   * distributed-mode overhead: turning on the networked shuffle path
//!     costs a constant, and stragglers/fetch-count growth add a
//!     per-node term (calibrated once, shared by both testbeds).

use crate::config::SimConfig;
use crate::sim::netsim::NetSim;
use crate::topology::Testbed;
use crate::transport::TcpModel;

/// Result of one simulated Hadoop benchmark.
#[derive(Clone, Debug)]
pub struct HadoopSimResult {
    pub terasort_secs: f64,
    pub terasplit_secs: f64,
    pub map_secs: f64,
    pub shuffle_secs: f64,
    pub reduce_secs: f64,
}

fn fits_in_cache(cfg: &SimConfig, bytes_per_node: f64) -> bool {
    bytes_per_node <= 0.7 * cfg.hardware.mem_bytes as f64
}

/// Simulate Hadoop Terasort with `bytes_per_node` input per node.
pub fn simulate_hadoop_terasort(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
) -> HadoopSimResult {
    let n = testbed.nodes();
    let h = &cfg.hadoop;
    let b = bytes_per_node;
    let read = cfg.hardware.disk_read_bps * h.io_efficiency;
    let write = cfg.hardware.disk_write_bps * h.io_efficiency;
    let cores = h.cores_used.min(cfg.hardware.cores) as f64;
    let cache = fits_in_cache(cfg, b);
    let cache_factor = if cache { 0.5 } else { 1.0 };

    // ---- map phase: read input, run map, spill partitioned output ----
    let blocks_per_node = (b / h.block_bytes as f64).ceil();
    let startup = blocks_per_node / cores * h.task_startup_secs;
    let map_cpu = b / (cfg.cpu.hadoop_map_bps * cores);
    let map_io = b / read + b / write;
    let map_secs = map_io.max(map_cpu) + startup;

    // ---- shuffle: local re-read + network fetches (overlapped w/ map) ----
    let local_shuffle_io = (b / read + b / write) * cache_factor * h.shuffle_http_overhead;
    let net_secs = if n > 1 {
        let mut net = NetSim::new();
        let links = testbed.build_network(&mut net);
        let tcp = TcpModel {
            wnd_max: 64.0 * 1024.0, // untuned 2008 defaults (paper §6.3:
            // "Hadoop may not have been [tested] using 10 Gb/s NICs")
            ..TcpModel::hadoop_shuffle()
        };
        for src in 0..n {
            for dst in 0..n {
                if src == dst {
                    continue;
                }
                let path = testbed.path(&links, src, dst);
                let bottleneck = testbed.bottleneck_bps(&net, &path);
                let rtt = testbed.rtt_secs(src, dst);
                // Hadoop 0.16: 2 concurrent reduce tasks per node
                // (tasktracker.reduce.tasks.maximum) x parallel.copies
                // fetchers, spread across the n-1 source nodes.
                let streams =
                    (2.0 * tcp.parallel_streams as f64 / (n as f64 - 1.0)).max(1.0);
                let cap = (tcp.stream_rate(bottleneck, rtt) * streams).min(bottleneck);
                net.start_flow(&path, b / n as f64, cap);
            }
        }
        net.run_to_idle()
    } else {
        0.0
    };
    // Hadoop overlaps fetches with the tail of the map phase.
    let shuffle_secs = 0.5 * local_shuffle_io.max(net_secs) + local_shuffle_io.min(net_secs) * 0.5;

    // ---- merge + reduce + output ----
    let merge_passes = if cache { h.merge_passes } else { h.merge_passes + 1.0 };
    let merge_io = merge_passes * (b / read + b / write) * cache_factor;
    let reduce_cpu = b / (cfg.cpu.hadoop_sort_bps * cores);
    // Job output goes through the HDFS client write pipeline.
    let hdfs_write = cfg.hardware.disk_write_bps * h.hdfs_write_efficiency;
    let output_io = h.replication_out as f64 * b / hdfs_write;
    let reduce_secs = merge_io.max(reduce_cpu) + output_io;

    // ---- distributed-mode overhead (shuffle servers + stragglers) ----
    let dist = if n > 1 { 60.0 + 30.0 * (n as f64 - 1.0) } else { 0.0 };

    HadoopSimResult {
        terasort_secs: map_secs + shuffle_secs + reduce_secs + dist,
        terasplit_secs: 0.0,
        map_secs,
        shuffle_secs,
        reduce_secs,
    }
}

/// Hadoop Terasplit: a single client streams the sorted output through
/// the entropy scan, reading HDFS over TCP sequentially per file (same
/// workload shape as the Sphere version, baseline software stack).
pub fn simulate_hadoop_terasplit(testbed: &Testbed, cfg: &SimConfig, bytes_per_node: f64) -> f64 {
    let h = &cfg.hadoop;
    let read = cfg.hardware.disk_read_bps * h.io_efficiency;
    let tcp = TcpModel {
        wnd_max: 64.0 * 1024.0,
        parallel_streams: 5,
        ..TcpModel::default()
    };
    // One-time job overhead: on the memory-starved generation the first
    // 10 GB scan fights the JVM heap for the page cache (GC churn while
    // the job spins up); absent on the 16 GB boxes (calibrated to the
    // Table 1 vs Table 2 single-node Terasplit cells).
    let mut total = if fits_in_cache(cfg, bytes_per_node) {
        0.0
    } else {
        230.0
    };
    for src in 0..testbed.nodes() {
        let rtt = testbed.rtt_secs(0, src);
        // HDFS bulk reads stream through DataNode pipes with sizeable
        // buffers; cross-site reads still pay the fetch setup.
        let net_cap = if src == 0 {
            f64::INFINITY
        } else {
            let bulk = TcpModel {
                wnd_max: 1024.0 * 1024.0,
                ..tcp
            };
            bulk.rate_cap(testbed.nic_bps, rtt)
        };
        // The Java client scans slower than the native one.
        let scan = cfg.cpu.scan_bps * 0.75;
        let rate = read.min(net_cap).min(scan);
        // A JVM fork per block-granular map task feeds the scan.
        let startups = (bytes_per_node / h.block_bytes as f64).ceil()
            / h.cores_used.max(1) as f64
            * h.task_startup_secs;
        total += bytes_per_node / rate + startups + tcp.setup_secs(rtt, false);
    }
    total
}

/// Hadoop file generation (§6.3): writing through the HDFS client
/// pipeline (paper measured 212 s per 10 GB file per node = 440 Mb/s).
pub fn simulate_hadoop_filegen(cfg: &SimConfig, bytes_per_node: f64) -> f64 {
    let write = cfg.hardware.disk_write_bps * cfg.hadoop.hdfs_write_efficiency;
    bytes_per_node / write * cfg.hadoop.replication_out as f64
}

/// Full Table-row simulation: Terasort + Terasplit.
pub fn simulate_hadoop_row(
    testbed: &Testbed,
    cfg: &SimConfig,
    bytes_per_node: f64,
) -> HadoopSimResult {
    let mut r = simulate_hadoop_terasort(testbed, cfg, bytes_per_node);
    r.terasplit_secs = simulate_hadoop_terasplit(testbed, cfg, bytes_per_node);
    r
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sphere::simjob::simulate_sphere_row;
    use crate::util::bytes::GB;

    #[test]
    fn single_node_wan_near_paper() {
        let t = Testbed::wan_testbed(1);
        let c = SimConfig::wan_default();
        let r = simulate_hadoop_row(&t, &c, 10.0 * GB as f64);
        // Paper Table 1: Hadoop Terasort 2312 s, Terasplit 460 s.
        assert!(
            (r.terasort_secs - 2312.0).abs() / 2312.0 < 0.25,
            "terasort {:.0} vs paper 2312",
            r.terasort_secs
        );
        assert!(
            (r.terasplit_secs - 460.0).abs() / 460.0 < 0.35,
            "terasplit {:.0} vs paper 460",
            r.terasplit_secs
        );
    }

    #[test]
    fn single_node_lan_near_paper() {
        let t = Testbed::lan_testbed(1);
        let c = SimConfig::lan_default();
        let r = simulate_hadoop_row(&t, &c, 10.0 * GB as f64);
        // Paper Table 2: Hadoop Terasort 645 s, Terasplit 141 s.
        assert!(
            (r.terasort_secs - 645.0).abs() / 645.0 < 0.25,
            "terasort {:.0} vs paper 645",
            r.terasort_secs
        );
        assert!(
            (r.terasplit_secs - 141.0).abs() / 141.0 < 0.35,
            "terasplit {:.0} vs paper 141",
            r.terasplit_secs
        );
    }

    #[test]
    fn sphere_beats_hadoop_everywhere() {
        // The paper's headline: speedups 2.4-2.6x (WAN sort), 1.6-2.3x
        // (LAN sort), 1.2-1.9x (split). Check who-wins at every sweep
        // point; exact factors are checked by the bench reports.
        let b = 10.0 * GB as f64;
        for n in 1..=6 {
            let t = Testbed::wan_testbed(n);
            let c = SimConfig::wan_default();
            let h = simulate_hadoop_row(&t, &c, b);
            let s = simulate_sphere_row(&t, &c, b);
            assert!(
                h.terasort_secs > 1.5 * s.terasort_secs,
                "WAN n={n}: hadoop {:.0} vs sphere {:.0}",
                h.terasort_secs,
                s.terasort_secs
            );
            assert!(h.terasplit_secs > s.terasplit_secs, "WAN split n={n}");
        }
        for n in 1..=8 {
            let t = Testbed::lan_testbed(n);
            let c = SimConfig::lan_default();
            let h = simulate_hadoop_row(&t, &c, b);
            let s = simulate_sphere_row(&t, &c, b);
            assert!(
                h.terasort_secs > 1.2 * s.terasort_secs,
                "LAN n={n}: hadoop {:.0} vs sphere {:.0}",
                h.terasort_secs,
                s.terasort_secs
            );
        }
    }

    #[test]
    fn filegen_ratio_matches_section_6_3() {
        // Paper: Hadoop 212 s vs Sphere 68 s per 10 GB file per node.
        let c = SimConfig::lan_default();
        let hadoop = simulate_hadoop_filegen(&c, 10.0 * GB as f64);
        let sphere = crate::sphere::simjob::simulate_sphere_filegen(&c, 10.0 * GB as f64);
        assert!((hadoop - 212.0).abs() / 212.0 < 0.25, "hadoop filegen {hadoop:.0}");
        let ratio = hadoop / sphere;
        assert!(
            (2.0..4.5).contains(&ratio),
            "filegen ratio {ratio:.1} (paper: 212/68 = 3.1)"
        );
    }

    #[test]
    fn hadoop_degrades_with_scale_even_on_lan() {
        let b = 10.0 * GB as f64;
        let c = SimConfig::lan_default();
        let r1 = simulate_hadoop_terasort(&Testbed::lan_testbed(1), &c, b);
        let r8 = simulate_hadoop_terasort(&Testbed::lan_testbed(8), &c, b);
        assert!(
            r8.terasort_secs > 1.25 * r1.terasort_secs,
            "paper: 645 -> 1000; got {:.0} -> {:.0}",
            r1.terasort_secs,
            r8.terasort_secs
        );
    }
}

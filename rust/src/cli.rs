//! Minimal command-line parser (offline environment: no `clap`).
//!
//! Supports `program <subcommand> --flag value --switch positional...`
//! with `--key=value` and `--key value` forms, typed accessors, and a
//! generated usage string.  Unknown flags are an error, which catches
//! typos in bench sweeps.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
}

impl Args {
    /// Parse argv (without the program name) against a flag spec.
    pub fn parse(
        argv: &[String],
        expect_subcommand: bool,
        spec: &[FlagSpec],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if expect_subcommand {
            match it.peek() {
                Some(s) if !s.starts_with('-') => {
                    out.subcommand = Some(it.next().unwrap().clone());
                }
                _ => {}
            }
        }
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let fs = spec
                    .iter()
                    .find(|f| f.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}"))?;
                if fs.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{name} requires a value"))?
                            .clone(),
                    };
                    out.flags.insert(name.to_string(), val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects a number, got {v:?}")),
        }
    }
}

/// Render a usage block for `--help`.
pub fn usage(program: &str, subcommands: &[(&str, &str)], spec: &[FlagSpec]) -> String {
    let mut s = format!("usage: {program} <command> [flags]\n\ncommands:\n");
    for (name, help) in subcommands {
        s.push_str(&format!("  {name:<16} {help}\n"));
    }
    s.push_str("\nflags:\n");
    for f in spec {
        let val = if f.takes_value { " <value>" } else { "" };
        s.push_str(&format!("  --{}{val:<10} {}\n", f.name, f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<FlagSpec> {
        vec![
            FlagSpec {
                name: "nodes",
                help: "node count",
                takes_value: true,
            },
            FlagSpec {
                name: "verbose",
                help: "chatty",
                takes_value: false,
            },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positionals() {
        let a = Args::parse(
            &sv(&["sort", "--nodes", "6", "--verbose", "input.dat"]),
            true,
            &spec(),
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("sort"));
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 6);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["input.dat"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["--nodes=8"]), false, &spec()).unwrap();
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 8);
    }

    #[test]
    fn rejects_unknown_and_malformed() {
        assert!(Args::parse(&sv(&["--bogus"]), false, &spec()).is_err());
        assert!(Args::parse(&sv(&["--nodes"]), false, &spec()).is_err());
        assert!(Args::parse(&sv(&["--verbose=1"]), false, &spec()).is_err());
        let a = Args::parse(&sv(&["--nodes", "abc"]), false, &spec()).unwrap();
        assert!(a.usize_or("nodes", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[], false, &spec()).unwrap();
        assert_eq!(a.usize_or("nodes", 4).unwrap(), 4);
        assert_eq!(a.f64_or("nodes", 1.5).unwrap(), 1.5);
        assert_eq!(a.str_or("nodes", "x"), "x");
        assert!(!a.has("verbose"));
    }

    #[test]
    fn usage_renders() {
        let u = usage("sector-sphere", &[("sort", "run terasort")], &spec());
        assert!(u.contains("sort"));
        assert!(u.contains("--nodes"));
    }
}

//! Service layer — multi-tenant client traffic against the storage
//! cloud (DESIGN.md §10).
//!
//! The paper evaluates Sector/Sphere as a *batch* system, but the
//! companion papers describe Sector's production role: a storage cloud
//! serving wide-area download traffic to many concurrent clients
//! (arXiv:0808.1802) across a growing multi-site testbed
//! (arXiv:0907.4810).  This module models that service side:
//!
//! * [`session::ClientSession`] — per-client state for the §4 access
//!   flow: a metadata lookup through the real Chord ring (short-cut by
//!   a TTL'd client-side metadata cache), replica selection preferring
//!   same-node / same-rack / same-site sources, a (cached) data
//!   connection, then a flow-level bulk transfer through `sim::netsim`.
//! * [`TrafficSpec`] — the workload description: an open-loop (Poisson
//!   arrival) or closed-loop (think-time) request stream over a Zipfian
//!   key catalog, mixed across named tenants with per-tenant request
//!   sizes and read/write ratios, from a population of up to millions
//!   of simulated clients.
//! * [`engine::run_traffic`] — the deterministic traffic engine:
//!   per-slave admission control (bounded queues, spill to the next
//!   replica, reject when every replica is saturated) with per-tenant
//!   round-robin fair scheduling, composed with the scenario fault
//!   plan (crashes re-route in-flight requests, WAN brown-outs squeeze
//!   cross-site transfers, stragglers slow their slave's disks).
//!
//! The output is an SLO report ([`TrafficReport`]): per-tenant
//! p50/p95/p99 latency, throughput, cache hit rates and
//! rejected/unavailable counts, wired into [`crate::metrics`].
//!
//! Specs parse from the `[traffic]` block of a scenario TOML
//! (`config/scenarios/traffic_*.toml`); a `[traffic]` block alone
//! switches `scenario::run_scenario` from the batch engine to this
//! one, and together with a `[workload]` block the two run colocated
//! on one shared substrate (`scenario::colocate`, DESIGN.md §11).

pub mod engine;
pub mod session;

pub use engine::{run_traffic, ElasticityReport, TenantSlo, TrafficReport};
pub use session::ClientSession;

use crate::config::Table;
use crate::sector::{ReplicaBounds, Scaler, StaticScaler, WatermarkScaler};
use crate::util::bytes::parse_bytes;

/// One tenant sharing the cloud.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of the request mix (normalized over all tenants).
    pub weight: f64,
    /// Fraction of this tenant's requests that are writes (uploads).
    pub write_fraction: f64,
    /// Bytes moved per request.
    pub object_bytes: f64,
    /// Scheduling priority class: lower drains first at every slave
    /// (0 = most urgent).  Requests round-robin across tenants *within*
    /// a class, so equal-priority tenants still share fairly.
    pub priority: u8,
}

/// How requests arrive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open loop: a Poisson stream at `rps` aggregate requests/second,
    /// each arrival drawn from the client population.  Load does not
    /// slow down when the cloud does — the overload regime.
    Open { rps: f64 },
    /// Closed loop: every client cycles request -> response -> think
    /// (exponential with mean `think_secs`).  Load self-clocks to the
    /// cloud's service rate — the saturation regime.
    Closed { think_secs: f64 },
}

/// Time-of-day modulation of the open-loop arrival rate, so demand
/// hotspots actually form and the elastic scaler has something to chase
/// (DESIGN.md §16).  Closed-loop runs ignore the shape (their rate is
/// set by service completions, not a clock).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalShape {
    /// Constant rate — the pre-elastic behaviour, and the default.
    Flat,
    /// A triangle wave with the given period: rate swings between
    /// `(1 - amplitude)` and `(1 + amplitude)` of nominal.  (A triangle
    /// rather than a sinusoid keeps the factor pure arithmetic — no
    /// libm calls in the deterministic hot path.)
    Diurnal { period_secs: f64, amplitude: f64 },
    /// A square wave: for the first `burst_secs` of every
    /// `period_secs`, rate is `(1 + amplitude)` of nominal; nominal
    /// otherwise.
    Bursty {
        period_secs: f64,
        burst_secs: f64,
        amplitude: f64,
    },
}

impl ArrivalShape {
    /// Multiplier on the nominal open-loop rate at sim time `now`.
    /// Floored well above zero so a deep trough never stalls the
    /// arrival process outright.
    pub fn rate_factor(&self, now: f64) -> f64 {
        match *self {
            ArrivalShape::Flat => 1.0,
            ArrivalShape::Diurnal { period_secs, amplitude } => {
                let phase = (now / period_secs).fract();
                // Triangle in [-1, 1]: rises over the first half period,
                // falls over the second.
                let tri = 1.0 - 4.0 * (phase - 0.5).abs();
                (1.0 + amplitude * tri).max(0.05)
            }
            ArrivalShape::Bursty { period_secs, burst_secs, amplitude } => {
                if (now % period_secs) < burst_secs {
                    1.0 + amplitude
                } else {
                    1.0
                }
            }
        }
    }
}

/// A complete traffic workload description (the `[traffic]` block).
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficSpec {
    /// Simulated client population (10^5..10^6 is the design range).
    pub clients: usize,
    /// Total requests to drive before draining.
    pub requests: u64,
    /// Distinct objects in the catalog.
    pub files: usize,
    /// Zipf popularity exponent over the catalog (must be positive;
    /// small values approach uniform).
    pub zipf_theta: f64,
    pub arrival: ArrivalProcess,
    /// Time-of-day modulation of the open-loop rate.
    pub shape: ArrivalShape,
    pub tenants: Vec<TenantSpec>,
}

impl TrafficSpec {
    /// Parse the `[traffic]` block (plus `[traffic.tenants.<name>]`
    /// subsections) of a scenario TOML.  Returns `None` when the
    /// document has no traffic block at all.  Unknown fields are an
    /// error — a typo'd key must not silently become a default.
    pub fn from_table(t: &Table) -> Result<Option<TrafficSpec>, String> {
        if t.section_keys("traffic").next().is_none() {
            return Ok(None);
        }
        t.check_known_keys(
            "traffic",
            &[
                "clients",
                "requests",
                "files",
                "zipf_theta",
                "arrival",
                "rps",
                "think_secs",
                "shape",
                "shape_period_secs",
                "shape_burst_secs",
                "shape_amplitude",
            ],
            &["tenants"],
        )?;
        let arrival = match t.str_or("traffic.arrival", "open") {
            "open" => ArrivalProcess::Open {
                rps: t.float_or("traffic.rps", 1000.0),
            },
            "closed" => ArrivalProcess::Closed {
                think_secs: t.float_or("traffic.think_secs", 1.0),
            },
            other => {
                return Err(format!(
                    "traffic.arrival: unknown process {other:?} (open|closed)"
                ))
            }
        };
        let shape = match t.str_or("traffic.shape", "flat") {
            "flat" => ArrivalShape::Flat,
            "diurnal" => ArrivalShape::Diurnal {
                period_secs: t.float_or("traffic.shape_period_secs", 86_400.0),
                amplitude: t.float_or("traffic.shape_amplitude", 0.5),
            },
            "bursty" => ArrivalShape::Bursty {
                period_secs: t.float_or("traffic.shape_period_secs", 60.0),
                burst_secs: t.float_or("traffic.shape_burst_secs", 10.0),
                amplitude: t.float_or("traffic.shape_amplitude", 2.0),
            },
            other => {
                return Err(format!(
                    "traffic.shape: unknown shape {other:?} (flat|diurnal|bursty)"
                ))
            }
        };
        let mut tenants = Vec::new();
        for label in t.subsections("traffic.tenants") {
            let k = |field: &str| format!("traffic.tenants.{label}.{field}");
            t.check_known_keys(
                &format!("traffic.tenants.{label}"),
                &["weight", "write_fraction", "object_bytes", "priority"],
                &[],
            )?;
            let priority = t.int_or(&k("priority"), 0);
            if !(0..=255).contains(&priority) {
                return Err(format!(
                    "tenant {label:?}: priority must be in [0, 255] (got {priority})"
                ));
            }
            tenants.push(TenantSpec {
                name: label.clone(),
                weight: t.float_or(&k("weight"), 1.0),
                write_fraction: t.float_or(&k("write_fraction"), 0.0),
                object_bytes: parse_bytes(t.str_or(&k("object_bytes"), "4MB"))? as f64,
                priority: priority as u8,
            });
        }
        if tenants.is_empty() {
            tenants.push(TenantSpec::default_tenant());
        }
        Ok(Some(TrafficSpec {
            clients: t.int_or("traffic.clients", 100_000).max(1) as usize,
            requests: t.int_or("traffic.requests", 100_000).max(1) as u64,
            files: t.int_or("traffic.files", 65_536).max(1) as usize,
            zipf_theta: t.float_or("traffic.zipf_theta", 0.9),
            arrival,
            shape,
            tenants,
        }))
    }

    /// Sanity-check a spec before running it.
    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 {
            return Err("traffic: clients must be >= 1".into());
        }
        // Sessions and catalog entries are indexed by u32 in the
        // engine's arenas; a larger population must be a named config
        // error here, never a silent truncation downstream.
        if self.clients > u32::MAX as usize {
            return Err(format!(
                "traffic: clients = {} overflows the u32 session index (max {})",
                self.clients,
                u32::MAX
            ));
        }
        if self.requests == 0 {
            return Err("traffic: requests must be >= 1".into());
        }
        if self.requests > u32::MAX as u64 {
            return Err(format!(
                "traffic: requests = {} overflows the u32 request index (max {})",
                self.requests,
                u32::MAX
            ));
        }
        if self.files == 0 {
            return Err("traffic: files must be >= 1".into());
        }
        if self.files > u32::MAX as usize {
            return Err(format!(
                "traffic: files = {} overflows the u32 catalog index (max {})",
                self.files,
                u32::MAX
            ));
        }
        if self.tenants.is_empty() {
            return Err("traffic: at least one tenant required".into());
        }
        let total: f64 = self.tenants.iter().map(|t| t.weight).sum();
        if !(total > 0.0) {
            return Err("traffic: tenant weights must sum to > 0".into());
        }
        for t in &self.tenants {
            if !(t.weight >= 0.0) {
                return Err(format!("tenant {:?}: weight must be >= 0", t.name));
            }
            if !(0.0..=1.0).contains(&t.write_fraction) {
                return Err(format!(
                    "tenant {:?}: write_fraction must be in [0, 1]",
                    t.name
                ));
            }
            if !(t.object_bytes > 0.0) {
                return Err(format!("tenant {:?}: object_bytes must be > 0", t.name));
            }
        }
        // `!(x > 0)` (not `x <= 0`) so NaN fails too.
        if !(self.zipf_theta > 0.0 && self.zipf_theta.is_finite()) {
            return Err(format!(
                "traffic: zipf_theta must be a positive finite exponent (got {})",
                self.zipf_theta
            ));
        }
        match self.arrival {
            ArrivalProcess::Open { rps } => {
                if !(rps > 0.0) {
                    return Err("traffic: open-loop rps must be > 0".into());
                }
            }
            ArrivalProcess::Closed { think_secs } => {
                if !(think_secs >= 0.0) {
                    return Err("traffic: think_secs must be >= 0".into());
                }
            }
        }
        match self.shape {
            ArrivalShape::Flat => {}
            ArrivalShape::Diurnal { period_secs, amplitude } => {
                if !(period_secs > 0.0 && period_secs.is_finite()) {
                    return Err("traffic: diurnal shape_period_secs must be > 0".into());
                }
                if !(0.0..=1.0).contains(&amplitude) {
                    return Err("traffic: diurnal shape_amplitude must be in [0, 1]".into());
                }
            }
            ArrivalShape::Bursty { period_secs, burst_secs, amplitude } => {
                if !(period_secs > 0.0 && period_secs.is_finite()) {
                    return Err("traffic: bursty shape_period_secs must be > 0".into());
                }
                if !(burst_secs > 0.0 && burst_secs <= period_secs) {
                    return Err(
                        "traffic: bursty shape_burst_secs must be in (0, period]".into()
                    );
                }
                if !(amplitude >= 0.0 && amplitude.is_finite()) {
                    return Err("traffic: bursty shape_amplitude must be >= 0".into());
                }
            }
        }
        Ok(())
    }
}

impl TenantSpec {
    /// The implicit single tenant when a `[traffic]` block names none.
    pub fn default_tenant() -> TenantSpec {
        TenantSpec {
            name: "default".into(),
            weight: 1.0,
            write_fraction: 0.1,
            object_bytes: 4.0e6,
            priority: 0,
        }
    }
}

/// Which autoscaling policy the `[replication]` block selects.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScalerPolicy {
    /// Replica counts stay at their initial placement — the baseline
    /// every elastic run is measured against.
    Static,
    /// Load-driven watermarks ([`WatermarkScaler`]).
    Watermark,
}

impl ScalerPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ScalerPolicy::Static => "static",
            ScalerPolicy::Watermark => "watermark",
        }
    }
}

/// The `[replication]` block: elastic replica management for the
/// traffic engine (DESIGN.md §16).  Absent block = static replication
/// with no scaler ticks at all, byte-identical to the pre-elastic
/// engine.
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicationSpec {
    pub policy: ScalerPolicy,
    /// Replica-count floor (>= 1; the initial placement starts here).
    pub min_replicas: u32,
    /// Replica-count ceiling (engine arenas are sized by this).
    pub max_replicas: u32,
    /// Scaler tick period, sim seconds.
    pub interval_secs: f64,
    /// Grow watermark: reads/sec/replica above this marks a file hot.
    pub high_reads_per_sec: f64,
    /// Shed watermark: reads/sec/replica below this marks a file cold.
    pub low_reads_per_sec: f64,
    /// Per-tick grow / shed budgets, so one burst cannot flood the
    /// network with re-replication transfers.
    pub max_grows_per_tick: u32,
    pub max_sheds_per_tick: u32,
}

impl ReplicationSpec {
    /// Parse the `[replication]` block.  Returns `None` when the
    /// document has no such block.
    pub fn from_table(t: &Table) -> Result<Option<ReplicationSpec>, String> {
        if t.section_keys("replication").next().is_none() {
            return Ok(None);
        }
        t.check_known_keys(
            "replication",
            &[
                "policy",
                "min_replicas",
                "max_replicas",
                "interval_secs",
                "high_reads_per_sec",
                "low_reads_per_sec",
                "max_grows_per_tick",
                "max_sheds_per_tick",
            ],
            &[],
        )?;
        let policy = match t.str_or("replication.policy", "watermark") {
            "static" => ScalerPolicy::Static,
            "watermark" => ScalerPolicy::Watermark,
            other => {
                return Err(format!(
                    "replication.policy: unknown policy {other:?} (static|watermark)"
                ))
            }
        };
        Ok(Some(ReplicationSpec {
            policy,
            min_replicas: t.int_or("replication.min_replicas", 2).max(0) as u32,
            max_replicas: t.int_or("replication.max_replicas", 4).max(0) as u32,
            interval_secs: t.float_or("replication.interval_secs", 1.0),
            high_reads_per_sec: t.float_or("replication.high_reads_per_sec", 4.0),
            low_reads_per_sec: t.float_or("replication.low_reads_per_sec", 0.5),
            max_grows_per_tick: t.int_or("replication.max_grows_per_tick", 32).max(0) as u32,
            max_sheds_per_tick: t.int_or("replication.max_sheds_per_tick", 32).max(0) as u32,
        }))
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.min_replicas < 1 {
            return Err("replication: min_replicas must be >= 1".into());
        }
        if self.max_replicas < self.min_replicas {
            return Err(format!(
                "replication: max_replicas ({}) must be >= min_replicas ({})",
                self.max_replicas, self.min_replicas
            ));
        }
        if self.max_replicas < 2 {
            return Err(
                "replication: max_replicas must be >= 2 — the initial catalog \
                 placement is always pair-replicated"
                    .into(),
            );
        }
        if self.max_replicas > 8 {
            return Err("replication: max_replicas must be <= 8".into());
        }
        if !(self.interval_secs > 0.0 && self.interval_secs.is_finite()) {
            return Err("replication: interval_secs must be > 0".into());
        }
        if !(self.low_reads_per_sec >= 0.0) {
            return Err("replication: low_reads_per_sec must be >= 0".into());
        }
        if !(self.high_reads_per_sec > self.low_reads_per_sec) {
            return Err(format!(
                "replication: high_reads_per_sec ({}) must exceed low_reads_per_sec ({})",
                self.high_reads_per_sec, self.low_reads_per_sec
            ));
        }
        Ok(())
    }

    /// The defaults the TOML parser fills in — what a bare
    /// `[replication]` block with just `policy` set resolves to.
    pub fn with_policy(policy: ScalerPolicy) -> ReplicationSpec {
        ReplicationSpec {
            policy,
            min_replicas: 2,
            max_replicas: 4,
            interval_secs: 1.0,
            high_reads_per_sec: 4.0,
            low_reads_per_sec: 0.5,
            max_grows_per_tick: 32,
            max_sheds_per_tick: 32,
        }
    }

    pub fn bounds(&self) -> ReplicaBounds {
        ReplicaBounds { min: self.min_replicas, max: self.max_replicas }
    }

    /// Build the configured policy object.
    pub fn scaler(&self) -> Box<dyn Scaler> {
        match self.policy {
            ScalerPolicy::Static => Box::new(StaticScaler),
            ScalerPolicy::Watermark => Box::new(WatermarkScaler {
                high: self.high_reads_per_sec,
                low: self.low_reads_per_sec,
                max_grows_per_tick: self.max_grows_per_tick,
                max_sheds_per_tick: self.max_sheds_per_tick,
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_traffic_block() {
        let t = Table::parse(
            r#"
            [traffic]
            clients = 1000
            requests = 5000
            files = 256
            zipf_theta = 0.8
            arrival = "open"
            rps = 500.0
            [traffic.tenants.fast]
            weight = 0.75
            write_fraction = 0.1
            object_bytes = "1MB"
            [traffic.tenants.bulk]
            weight = 0.25
            object_bytes = "16MB"
            "#,
        )
        .unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.clients, 1000);
        assert_eq!(spec.requests, 5000);
        assert_eq!(spec.tenants.len(), 2);
        assert_eq!(spec.tenants[0].name, "bulk", "subsections sort by name");
        assert!((spec.tenants[1].object_bytes - 1.0e6).abs() < 1.0);
        assert_eq!(spec.arrival, ArrivalProcess::Open { rps: 500.0 });
        spec.validate().unwrap();
    }

    #[test]
    fn absent_block_is_none() {
        let t = Table::parse("[workload]\nkind = \"terasort\"").unwrap();
        assert_eq!(TrafficSpec::from_table(&t).unwrap(), None);
    }

    #[test]
    fn default_tenant_fills_in() {
        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.tenants.len(), 1);
        assert_eq!(spec.tenants[0].name, "default");
        spec.validate().unwrap();
    }

    #[test]
    fn rejects_typos_and_bad_values() {
        let typo = Table::parse("[traffic]\nrequets = 10").unwrap();
        let err = TrafficSpec::from_table(&typo).unwrap_err();
        assert!(err.contains("requets"), "{err}");
        let tenant_typo =
            Table::parse("[traffic]\nrequests = 10\n[traffic.tenants.a]\nwieght = 1.0").unwrap();
        let err = TrafficSpec::from_table(&tenant_typo).unwrap_err();
        assert!(err.contains("wieght"), "{err}");
        let bad_arrival = Table::parse("[traffic]\narrival = \"psychic\"").unwrap();
        assert!(TrafficSpec::from_table(&bad_arrival).is_err());

        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let mut spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        spec.tenants[0].write_fraction = 1.5;
        assert!(spec.validate().is_err());
        spec.tenants[0].write_fraction = 0.5;
        spec.tenants[0].object_bytes = 0.0;
        assert!(spec.validate().is_err());
        spec.tenants[0].object_bytes = 1.0e6;
        spec.arrival = ArrivalProcess::Open { rps: 0.0 };
        assert!(spec.validate().is_err());
        // Zero-sized populations must fail validation, not panic in
        // the engine (the CLI writes raw values past the parse clamp).
        spec.arrival = ArrivalProcess::Open { rps: 100.0 };
        spec.clients = 0;
        assert!(spec.validate().is_err());
        spec.clients = 10;
        spec.requests = 0;
        assert!(spec.validate().is_err());
        spec.requests = 10;
        spec.files = 0;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn rejects_nonpositive_zipf_exponents() {
        // A zero/negative/NaN exponent must be a named config error,
        // not a downstream panic in the catalog sampler.
        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let mut spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        for bad in [0.0, -0.5, f64::NAN, f64::INFINITY] {
            spec.zipf_theta = bad;
            let err = spec.validate().unwrap_err();
            assert!(err.contains("zipf_theta"), "{bad}: {err}");
        }
        spec.zipf_theta = 0.9;
        spec.validate().unwrap();
    }

    #[test]
    fn rejects_populations_that_overflow_the_session_index() {
        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let mut spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        spec.clients = u32::MAX as usize + 1;
        let err = spec.validate().unwrap_err();
        assert!(err.contains("session index"), "{err}");
        spec.clients = u32::MAX as usize;
        spec.validate().unwrap();
        spec.requests = u32::MAX as u64 + 1;
        let err = spec.validate().unwrap_err();
        assert!(err.contains("request index"), "{err}");
        spec.requests = 10;
        spec.files = u32::MAX as usize + 1;
        let err = spec.validate().unwrap_err();
        assert!(err.contains("catalog index"), "{err}");
    }

    #[test]
    fn closed_loop_parses() {
        let t = Table::parse("[traffic]\narrival = \"closed\"\nthink_secs = 2.0").unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.arrival, ArrivalProcess::Closed { think_secs: 2.0 });
    }

    #[test]
    fn arrival_shapes_parse_and_modulate() {
        let t = Table::parse(
            "[traffic]\nshape = \"bursty\"\nshape_period_secs = 10.0\n\
             shape_burst_secs = 2.0\nshape_amplitude = 3.0",
        )
        .unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        let shape = spec.shape;
        assert_eq!(
            shape,
            ArrivalShape::Bursty { period_secs: 10.0, burst_secs: 2.0, amplitude: 3.0 }
        );
        spec.validate().unwrap();
        assert_eq!(shape.rate_factor(1.0), 4.0, "inside the burst");
        assert_eq!(shape.rate_factor(5.0), 1.0, "outside the burst");
        assert_eq!(shape.rate_factor(11.0), 4.0, "bursts recur every period");

        let diurnal = ArrivalShape::Diurnal { period_secs: 100.0, amplitude: 0.5 };
        assert!((diurnal.rate_factor(50.0) - 1.5).abs() < 1e-12, "peak at mid-period");
        assert!((diurnal.rate_factor(0.0) - 0.5).abs() < 1e-12, "trough at the boundary");
        assert_eq!(ArrivalShape::Flat.rate_factor(123.0), 1.0);

        let bad = Table::parse("[traffic]\nshape = \"square\"").unwrap();
        assert!(TrafficSpec::from_table(&bad).unwrap_err().contains("square"));
        let mut spec = TrafficSpec::from_table(&Table::parse("[traffic]\n").unwrap())
            .unwrap()
            .unwrap();
        spec.shape = ArrivalShape::Diurnal { period_secs: 0.0, amplitude: 0.5 };
        assert!(spec.validate().is_err());
        spec.shape = ArrivalShape::Bursty { period_secs: 5.0, burst_secs: 6.0, amplitude: 1.0 };
        assert!(spec.validate().is_err());
    }

    #[test]
    fn tenant_priority_parses_and_rejects_out_of_range() {
        let t = Table::parse(
            "[traffic]\nrequests = 10\n[traffic.tenants.a]\npriority = 2",
        )
        .unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.tenants[0].priority, 2);
        let bad = Table::parse(
            "[traffic]\nrequests = 10\n[traffic.tenants.a]\npriority = 300",
        )
        .unwrap();
        let err = TrafficSpec::from_table(&bad).unwrap_err();
        assert!(err.contains("priority"), "{err}");
    }

    #[test]
    fn replication_block_parses_with_defaults() {
        let none = Table::parse("[traffic]\nrequests = 10").unwrap();
        assert_eq!(ReplicationSpec::from_table(&none).unwrap(), None);

        let t = Table::parse("[replication]\npolicy = \"watermark\"").unwrap();
        let spec = ReplicationSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec, ReplicationSpec::with_policy(ScalerPolicy::Watermark));
        spec.validate().unwrap();
        assert_eq!(spec.scaler().name(), "watermark");
        assert_eq!(spec.bounds(), crate::sector::ReplicaBounds { min: 2, max: 4 });

        let t = Table::parse(
            "[replication]\npolicy = \"static\"\nmin_replicas = 1\nmax_replicas = 6\n\
             interval_secs = 0.5\nhigh_reads_per_sec = 10.0\nlow_reads_per_sec = 1.0\n\
             max_grows_per_tick = 4\nmax_sheds_per_tick = 2",
        )
        .unwrap();
        let spec = ReplicationSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.policy, ScalerPolicy::Static);
        assert_eq!(spec.max_replicas, 6);
        assert_eq!(spec.scaler().name(), "static");
        spec.validate().unwrap();
    }

    #[test]
    fn replication_block_rejects_typos_and_bad_values() {
        let typo = Table::parse("[replication]\npollicy = \"static\"").unwrap();
        let err = ReplicationSpec::from_table(&typo).unwrap_err();
        assert!(err.contains("pollicy"), "{err}");
        let bad = Table::parse("[replication]\npolicy = \"psychic\"").unwrap();
        assert!(ReplicationSpec::from_table(&bad).is_err());

        let mut spec = ReplicationSpec::with_policy(ScalerPolicy::Watermark);
        spec.min_replicas = 0;
        assert!(spec.validate().is_err());
        spec = ReplicationSpec::with_policy(ScalerPolicy::Watermark);
        spec.max_replicas = 1;
        assert!(spec.validate().is_err(), "max below min");
        spec = ReplicationSpec::with_policy(ScalerPolicy::Watermark);
        spec.max_replicas = 9;
        assert!(spec.validate().is_err());
        spec = ReplicationSpec::with_policy(ScalerPolicy::Watermark);
        spec.interval_secs = 0.0;
        assert!(spec.validate().is_err());
        spec = ReplicationSpec::with_policy(ScalerPolicy::Watermark);
        spec.high_reads_per_sec = spec.low_reads_per_sec;
        assert!(spec.validate().is_err(), "watermarks must be ordered");
    }
}

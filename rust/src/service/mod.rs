//! Service layer — multi-tenant client traffic against the storage
//! cloud (DESIGN.md §10).
//!
//! The paper evaluates Sector/Sphere as a *batch* system, but the
//! companion papers describe Sector's production role: a storage cloud
//! serving wide-area download traffic to many concurrent clients
//! (arXiv:0808.1802) across a growing multi-site testbed
//! (arXiv:0907.4810).  This module models that service side:
//!
//! * [`session::ClientSession`] — per-client state for the §4 access
//!   flow: a metadata lookup through the real Chord ring (short-cut by
//!   a TTL'd client-side metadata cache), replica selection preferring
//!   same-node / same-rack / same-site sources, a (cached) data
//!   connection, then a flow-level bulk transfer through `sim::netsim`.
//! * [`TrafficSpec`] — the workload description: an open-loop (Poisson
//!   arrival) or closed-loop (think-time) request stream over a Zipfian
//!   key catalog, mixed across named tenants with per-tenant request
//!   sizes and read/write ratios, from a population of up to millions
//!   of simulated clients.
//! * [`engine::run_traffic`] — the deterministic traffic engine:
//!   per-slave admission control (bounded queues, spill to the next
//!   replica, reject when every replica is saturated) with per-tenant
//!   round-robin fair scheduling, composed with the scenario fault
//!   plan (crashes re-route in-flight requests, WAN brown-outs squeeze
//!   cross-site transfers, stragglers slow their slave's disks).
//!
//! The output is an SLO report ([`TrafficReport`]): per-tenant
//! p50/p95/p99 latency, throughput, cache hit rates and
//! rejected/unavailable counts, wired into [`crate::metrics`].
//!
//! Specs parse from the `[traffic]` block of a scenario TOML
//! (`config/scenarios/traffic_*.toml`); a `[traffic]` block alone
//! switches `scenario::run_scenario` from the batch engine to this
//! one, and together with a `[workload]` block the two run colocated
//! on one shared substrate (`scenario::colocate`, DESIGN.md §11).

pub mod engine;
pub mod session;

pub use engine::{run_traffic, TenantSlo, TrafficReport};
pub use session::ClientSession;

use crate::config::Table;
use crate::util::bytes::parse_bytes;

/// One tenant sharing the cloud.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    pub name: String,
    /// Relative share of the request mix (normalized over all tenants).
    pub weight: f64,
    /// Fraction of this tenant's requests that are writes (uploads).
    pub write_fraction: f64,
    /// Bytes moved per request.
    pub object_bytes: f64,
}

/// How requests arrive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Open loop: a Poisson stream at `rps` aggregate requests/second,
    /// each arrival drawn from the client population.  Load does not
    /// slow down when the cloud does — the overload regime.
    Open { rps: f64 },
    /// Closed loop: every client cycles request -> response -> think
    /// (exponential with mean `think_secs`).  Load self-clocks to the
    /// cloud's service rate — the saturation regime.
    Closed { think_secs: f64 },
}

/// A complete traffic workload description (the `[traffic]` block).
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficSpec {
    /// Simulated client population (10^5..10^6 is the design range).
    pub clients: usize,
    /// Total requests to drive before draining.
    pub requests: u64,
    /// Distinct objects in the catalog.
    pub files: usize,
    /// Zipf popularity exponent over the catalog (0 = uniform).
    pub zipf_theta: f64,
    pub arrival: ArrivalProcess,
    pub tenants: Vec<TenantSpec>,
}

impl TrafficSpec {
    /// Parse the `[traffic]` block (plus `[traffic.tenants.<name>]`
    /// subsections) of a scenario TOML.  Returns `None` when the
    /// document has no traffic block at all.  Unknown fields are an
    /// error — a typo'd key must not silently become a default.
    pub fn from_table(t: &Table) -> Result<Option<TrafficSpec>, String> {
        if t.section_keys("traffic").next().is_none() {
            return Ok(None);
        }
        t.check_known_keys(
            "traffic",
            &[
                "clients",
                "requests",
                "files",
                "zipf_theta",
                "arrival",
                "rps",
                "think_secs",
            ],
            &["tenants"],
        )?;
        let arrival = match t.str_or("traffic.arrival", "open") {
            "open" => ArrivalProcess::Open {
                rps: t.float_or("traffic.rps", 1000.0),
            },
            "closed" => ArrivalProcess::Closed {
                think_secs: t.float_or("traffic.think_secs", 1.0),
            },
            other => {
                return Err(format!(
                    "traffic.arrival: unknown process {other:?} (open|closed)"
                ))
            }
        };
        let mut tenants = Vec::new();
        for label in t.subsections("traffic.tenants") {
            let k = |field: &str| format!("traffic.tenants.{label}.{field}");
            t.check_known_keys(
                &format!("traffic.tenants.{label}"),
                &["weight", "write_fraction", "object_bytes"],
                &[],
            )?;
            tenants.push(TenantSpec {
                name: label.clone(),
                weight: t.float_or(&k("weight"), 1.0),
                write_fraction: t.float_or(&k("write_fraction"), 0.0),
                object_bytes: parse_bytes(t.str_or(&k("object_bytes"), "4MB"))? as f64,
            });
        }
        if tenants.is_empty() {
            tenants.push(TenantSpec::default_tenant());
        }
        Ok(Some(TrafficSpec {
            clients: t.int_or("traffic.clients", 100_000).max(1) as usize,
            requests: t.int_or("traffic.requests", 100_000).max(1) as u64,
            files: t.int_or("traffic.files", 65_536).max(1) as usize,
            zipf_theta: t.float_or("traffic.zipf_theta", 0.9),
            arrival,
            tenants,
        }))
    }

    /// Sanity-check a spec before running it.
    pub fn validate(&self) -> Result<(), String> {
        if self.clients == 0 {
            return Err("traffic: clients must be >= 1".into());
        }
        if self.requests == 0 {
            return Err("traffic: requests must be >= 1".into());
        }
        if self.files == 0 {
            return Err("traffic: files must be >= 1".into());
        }
        if self.tenants.is_empty() {
            return Err("traffic: at least one tenant required".into());
        }
        let total: f64 = self.tenants.iter().map(|t| t.weight).sum();
        if !(total > 0.0) {
            return Err("traffic: tenant weights must sum to > 0".into());
        }
        for t in &self.tenants {
            if !(t.weight >= 0.0) {
                return Err(format!("tenant {:?}: weight must be >= 0", t.name));
            }
            if !(0.0..=1.0).contains(&t.write_fraction) {
                return Err(format!(
                    "tenant {:?}: write_fraction must be in [0, 1]",
                    t.name
                ));
            }
            if !(t.object_bytes > 0.0) {
                return Err(format!("tenant {:?}: object_bytes must be > 0", t.name));
            }
        }
        if !(self.zipf_theta >= 0.0) {
            return Err("traffic: zipf_theta must be >= 0".into());
        }
        match self.arrival {
            ArrivalProcess::Open { rps } => {
                if !(rps > 0.0) {
                    return Err("traffic: open-loop rps must be > 0".into());
                }
            }
            ArrivalProcess::Closed { think_secs } => {
                if !(think_secs >= 0.0) {
                    return Err("traffic: think_secs must be >= 0".into());
                }
            }
        }
        Ok(())
    }
}

impl TenantSpec {
    /// The implicit single tenant when a `[traffic]` block names none.
    pub fn default_tenant() -> TenantSpec {
        TenantSpec {
            name: "default".into(),
            weight: 1.0,
            write_fraction: 0.1,
            object_bytes: 4.0e6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_traffic_block() {
        let t = Table::parse(
            r#"
            [traffic]
            clients = 1000
            requests = 5000
            files = 256
            zipf_theta = 0.8
            arrival = "open"
            rps = 500.0
            [traffic.tenants.fast]
            weight = 0.75
            write_fraction = 0.1
            object_bytes = "1MB"
            [traffic.tenants.bulk]
            weight = 0.25
            object_bytes = "16MB"
            "#,
        )
        .unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.clients, 1000);
        assert_eq!(spec.requests, 5000);
        assert_eq!(spec.tenants.len(), 2);
        assert_eq!(spec.tenants[0].name, "bulk", "subsections sort by name");
        assert!((spec.tenants[1].object_bytes - 1.0e6).abs() < 1.0);
        assert_eq!(spec.arrival, ArrivalProcess::Open { rps: 500.0 });
        spec.validate().unwrap();
    }

    #[test]
    fn absent_block_is_none() {
        let t = Table::parse("[workload]\nkind = \"terasort\"").unwrap();
        assert_eq!(TrafficSpec::from_table(&t).unwrap(), None);
    }

    #[test]
    fn default_tenant_fills_in() {
        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.tenants.len(), 1);
        assert_eq!(spec.tenants[0].name, "default");
        spec.validate().unwrap();
    }

    #[test]
    fn rejects_typos_and_bad_values() {
        let typo = Table::parse("[traffic]\nrequets = 10").unwrap();
        let err = TrafficSpec::from_table(&typo).unwrap_err();
        assert!(err.contains("requets"), "{err}");
        let tenant_typo =
            Table::parse("[traffic]\nrequests = 10\n[traffic.tenants.a]\nwieght = 1.0").unwrap();
        let err = TrafficSpec::from_table(&tenant_typo).unwrap_err();
        assert!(err.contains("wieght"), "{err}");
        let bad_arrival = Table::parse("[traffic]\narrival = \"psychic\"").unwrap();
        assert!(TrafficSpec::from_table(&bad_arrival).is_err());

        let t = Table::parse("[traffic]\nrequests = 10").unwrap();
        let mut spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        spec.tenants[0].write_fraction = 1.5;
        assert!(spec.validate().is_err());
        spec.tenants[0].write_fraction = 0.5;
        spec.tenants[0].object_bytes = 0.0;
        assert!(spec.validate().is_err());
        spec.tenants[0].object_bytes = 1.0e6;
        spec.arrival = ArrivalProcess::Open { rps: 0.0 };
        assert!(spec.validate().is_err());
        // Zero-sized populations must fail validation, not panic in
        // the engine (the CLI writes raw values past the parse clamp).
        spec.arrival = ArrivalProcess::Open { rps: 100.0 };
        spec.clients = 0;
        assert!(spec.validate().is_err());
        spec.clients = 10;
        spec.requests = 0;
        assert!(spec.validate().is_err());
        spec.requests = 10;
        spec.files = 0;
        assert!(spec.validate().is_err());
    }

    #[test]
    fn closed_loop_parses() {
        let t = Table::parse("[traffic]\narrival = \"closed\"\nthink_secs = 2.0").unwrap();
        let spec = TrafficSpec::from_table(&t).unwrap().unwrap();
        assert_eq!(spec.arrival, ArrivalProcess::Closed { think_secs: 2.0 });
    }
}

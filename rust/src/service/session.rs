//! Client sessions — per-client state for the §4 access flow.
//!
//! Each simulated client holds the two things the paper gives a Sector
//! client: a small TTL'd cache of recently resolved metadata (step 2
//! short-circuit: a repeat request needs no Chord lookup while the
//! entry is fresh) and a preference order over a file's replicas
//! ("the routing layer can use information involving network bandwidth
//! and latency", §4 — modeled as same-node > same-rack > same-site >
//! anywhere).  Sessions are deliberately tiny: the engine materializes
//! up to a million of them.

use crate::topology::Testbed;

/// One simulated client.
#[derive(Clone, Debug)]
pub struct ClientSession {
    pub id: u32,
    /// Attachment node: the edge server the client connects through.
    /// Stays a valid network endpoint even if the node's storage role
    /// crashes (the NIC and switch ports outlive the slave process).
    pub node: u32,
    /// Metadata cache: (key, expires_at) in LRU order, most recent
    /// last.  Lazily allocated — idle members of a million-client
    /// population cost only the struct itself.
    meta: Vec<(u64, f64)>,
}

impl ClientSession {
    pub fn new(id: u32, node: u32) -> ClientSession {
        ClientSession {
            id,
            node,
            meta: Vec::new(),
        }
    }

    /// §4 step 2 short-circuit: does this session hold a fresh metadata
    /// entry for `key` at time `now`?  A hit refreshes the entry's LRU
    /// position but NOT its expiry — cached metadata goes stale on the
    /// original resolution's clock.
    pub fn meta_lookup(&mut self, key: u64, now: f64) -> bool {
        if let Some(pos) = self.meta.iter().position(|&(k, _)| k == key) {
            if self.meta[pos].1 > now {
                let entry = self.meta.remove(pos);
                self.meta.push(entry);
                return true;
            }
            self.meta.remove(pos);
        }
        false
    }

    /// Record a resolved lookup, evicting the least-recently-used entry
    /// beyond `capacity`.
    pub fn meta_insert(&mut self, key: u64, expires_at: f64, capacity: usize) {
        if capacity == 0 {
            return;
        }
        self.meta.retain(|&(k, _)| k != key);
        while self.meta.len() >= capacity {
            self.meta.remove(0);
        }
        self.meta.push((key, expires_at));
    }

    pub fn meta_len(&self) -> usize {
        self.meta.len()
    }
}

/// Order candidate replicas by the client's network preference:
/// same node, then same rack, then same site, then anywhere — ties
/// broken by the lower node id so the order is deterministic.
pub fn rank_replicas(testbed: &Testbed, home: usize, replicas: &mut [u32]) {
    replicas.sort_by_key(|&r| (testbed.proximity(home, r as usize), r));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::TopologySpec;

    #[test]
    fn meta_cache_hits_within_ttl() {
        let mut s = ClientSession::new(0, 3);
        assert!(!s.meta_lookup(42, 0.0), "cold cache misses");
        s.meta_insert(42, 10.0, 4);
        assert!(s.meta_lookup(42, 5.0));
        assert!(!s.meta_lookup(42, 10.0), "expiry is exclusive");
        assert_eq!(s.meta_len(), 0, "expired entry is dropped on lookup");
    }

    #[test]
    fn meta_cache_is_lru_bounded() {
        let mut s = ClientSession::new(0, 0);
        for k in 0..4u64 {
            s.meta_insert(k, 100.0, 2);
        }
        assert_eq!(s.meta_len(), 2);
        assert!(!s.meta_lookup(0, 1.0), "old entries evicted");
        assert!(s.meta_lookup(2, 1.0));
        assert!(s.meta_lookup(3, 1.0));
        // A hit refreshes recency: inserting one more evicts key 3,
        // not the just-touched key 2.
        s.meta_lookup(2, 1.0);
        s.meta_insert(9, 100.0, 2);
        assert!(s.meta_lookup(2, 1.0));
        assert!(!s.meta_lookup(3, 1.0));
    }

    #[test]
    fn replica_ranking_prefers_proximity() {
        // scale_out(2, 2, 2): nodes 0-1 rack 0, 2-3 rack 1 (site 0),
        // 4-7 site 1.
        let t = TopologySpec::scale_out(2, 2, 2).generate().unwrap();
        let mut replicas = vec![6, 2, 0, 1];
        rank_replicas(&t, 0, &mut replicas);
        assert_eq!(replicas, vec![0, 1, 2, 6], "local, rack, site, wan");
        let mut replicas = vec![5, 3];
        rank_replicas(&t, 4, &mut replicas);
        assert_eq!(replicas, vec![5, 3], "same-site beats cross-site");
    }
}

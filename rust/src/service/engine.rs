//! The traffic engine — deterministic, event-driven service of client
//! requests against a simulated Sector cloud (DESIGN.md §10).
//!
//! Every request walks the §4 access flow:
//!
//!   1. the client's session checks its metadata cache; on a miss the
//!      lookup routes through the real [`ChordRing`] (hop count × mean
//!      overlay RTT + the response RTT), and the answer is cached with
//!      a TTL;
//!   2. replicas are ranked same-node > same-rack > same-site > WAN
//!      and the request is admitted at the first replica with a free
//!      service slot, queued at the first with queue room, or rejected
//!      when every live replica is saturated (bounded queues: overload
//!      degrades by shedding, not by queueing without limit);
//!   3. a (cached) data connection is acquired — a cache miss pays one
//!      handshake RTT (§4: "frequent data transfers between the same
//!      pair of nodes do not need to set up a data connection every
//!      time");
//!   4. the bytes ride a `sim::netsim` flow whose path includes the
//!      slave's disk (a per-node link, so concurrent slots share the
//!      spindle), the node NICs and any rack/site uplinks — WAN
//!      brown-outs and stragglers therefore squeeze exactly the flows
//!      that cross them.
//!
//! Fair scheduling: each slave drains its bounded queue round-robin
//! across tenants, so a backlogged bulk tenant cannot starve an
//! interactive one.  Faults compose with the stream: a crash cancels
//! the dead slave's flows and re-dispatches its requests to surviving
//! replicas (clients' edge attachment outlives the storage process —
//! the NIC and switch ports are still there), and the Chord ring drops
//! the node so later lookups route to its successor.
//!
//! Determinism contract: same spec, same report, byte for byte — all
//! randomness flows from the spec seed through forked [`Pcg64`]
//! streams, and every container iterated during the run is ordered.
//!
//! Substrate sharing: the engine does NOT own its network, event queue
//! or fault state — every method borrows them from the driving loop.
//! `run_traffic` is the standalone driver (service-only scenarios),
//! a thin [`core::Harness`] over the shared engine core (DESIGN.md
//! §14); `scenario::colocate` drives the same engine interleaved with
//! a batch Sphere job on one shared substrate (DESIGN.md §11).
//!
//! Elastic replication (DESIGN.md §16): with a `[replication]` block
//! the engine keeps per-file replica *sets* in a flat arena (up to
//! `max_replicas` slots per file) and a periodic `ScalerTick` event
//! feeds one window of per-file demand to the configured
//! [`Scaler`] policy.  Grow directives become real transfer flows on
//! the shared network (contending with serving traffic; the new copy
//! serves only once the bytes land); shed directives drain — the
//! replica leaves the read set immediately but its data is removed
//! only after every admitted request pinned to it completes.  Without
//! the block, and under `policy = "static"`, no tick is ever scheduled
//! and the request timeline is byte-identical to the pre-elastic
//! engine.

use std::collections::{BTreeMap, VecDeque};

use crate::config::{SimConfig, TransportKind};
use crate::metrics::Metrics;
use crate::routing::chord::{ChordRing, hash_name};
use crate::scenario::core::{self, CoreEv, FaultEv, Harness};
use crate::scenario::engine::FaultState;
use crate::scenario::trace::{HarnessGauges, TraceRecorder, Tracer};
use crate::scenario::{ScenarioReport, ScenarioSpec, TenantSloDelta, TierBytes};
use crate::sector::{FileLoad, ReplicaDirective, Scaler};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::simjob::udt_efficiency;
use crate::topology::{NetLinks, Proximity, Testbed, rack_diverse_replica};
use crate::transport::{ConnectionCache, TransportModels};
use crate::util::rng::{Pcg64, SplitMix64};
use crate::util::stats::Summary;

use super::session::{ClientSession, rank_replicas};
use super::{ArrivalProcess, ArrivalShape, ReplicationSpec, ScalerPolicy, TrafficSpec};

/// Re-dispatch budget per request (crash re-routes).
const MAX_ATTEMPTS: u8 = 4;

/// Per-tenant service-level objective measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSlo {
    pub name: String,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub unavailable: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Completed requests per simulated second.
    pub throughput_rps: f64,
    pub gbytes: f64,
}

/// What a traffic run produced (the SLO report).
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficReport {
    pub tenants: Vec<TenantSlo>,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub unavailable: u64,
    pub makespan_secs: f64,
    /// Client-side metadata cache hit rate (§4 step 2 short-circuit).
    pub meta_hit_rate: f64,
    /// Node-pair data-connection cache hit rate (§4).
    pub conn_hit_rate: f64,
    /// Requests re-dispatched after a slave crash.
    pub reassignments: u64,
    /// Background write-replication volume (not client-visible).
    pub replica_gbytes: f64,
    /// Fraction of completed requests served same-node or same-rack.
    pub near_fraction: f64,
    /// Deepest any slave's admission queue got.
    pub peak_queue: usize,
    /// Distinct client sessions actually materialized.  Open-loop
    /// populations are lazy: this stays bounded by the request count,
    /// never by the (possibly million-client) population.
    pub sessions_touched: u64,
}

/// What elastic replication did during a traffic run (DESIGN.md §16).
/// Present whenever the scenario carried a `[replication]` block;
/// under `policy = "watermark"` the engine also runs the identical
/// trace under static replication and reports per-tenant SLO deltas
/// against it (negative delta = the scaler improved that percentile).
#[derive(Clone, Debug, PartialEq)]
pub struct ElasticityReport {
    /// Name of the policy that ran ("static" | "watermark").
    pub policy: &'static str,
    /// Grow / shed directives the engine actually applied.
    pub grows: u64,
    pub sheds: u64,
    /// Sheds that had to wait for in-flight reads to drain before the
    /// replica's data could be removed.
    pub drained_sheds: u64,
    /// Re-replication transfer volume by deepest link tier crossed —
    /// the network cost of elasticity, distinct from serving traffic.
    pub rereplication: TierBytes,
    /// Most live replicas held at any scaler tick, summed over files.
    pub peak_replicas: u64,
    /// Live replicas at the end of the run.
    pub final_replicas: u64,
    /// (sim time, total live replicas) at each scaler tick, capped at
    /// [`TIMELINE_CAP`] points.
    pub replica_timeline: Vec<(f64, u64)>,
    /// Invariant breaches observed while running (replica on a dead
    /// node, bounds violation, drain accounting underflow).  Always 0
    /// on a correct engine; the property suite asserts it.
    pub invariant_violations: u64,
    /// Per-tenant p50/p95/p99 deltas vs the embedded static baseline
    /// (watermark policy only; empty under static).
    pub tenant_deltas: Vec<TenantSloDelta>,
}

/// Retention cap for [`ElasticityReport::replica_timeline`].
const TIMELINE_CAP: usize = 4096;

impl TrafficReport {
    /// Record the report into a shared metrics registry (counters for
    /// totals, gauges for the per-tenant percentiles in ms).
    pub fn record_into(&self, m: &Metrics) {
        m.add("service.requests", self.requests);
        m.add("service.completed", self.completed);
        m.add("service.rejected", self.rejected);
        m.add("service.unavailable", self.unavailable);
        m.add("service.reassignments", self.reassignments);
        m.gauge_set("service.peak_queue", self.peak_queue as i64);
        m.gauge_set(
            "service.meta_hit_pct",
            (self.meta_hit_rate * 100.0).round() as i64,
        );
        m.gauge_set(
            "service.conn_hit_pct",
            (self.conn_hit_rate * 100.0).round() as i64,
        );
        for t in &self.tenants {
            m.add(&format!("service.{}.completed", t.name), t.completed);
            m.add(&format!("service.{}.rejected", t.name), t.rejected);
            m.gauge_set(
                &format!("service.{}.p99_ms", t.name),
                t.p99_ms.round() as i64,
            );
        }
    }
}

/// Run a service-only traffic scenario to completion.  Deterministic:
/// no wall clock, no ambient randomness — the spec is the only input.
/// This is the standalone driver; colocated scenarios drive the same
/// [`Engine`] from `scenario::colocate` instead.
pub fn run_traffic(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<ScenarioReport, String> {
    let tspec = spec
        .traffic
        .as_ref()
        .ok_or("run_traffic called without a [traffic] block")?;
    tspec.validate()?;
    // Elastic runs embed their own control: the identical trace under
    // static replication on an identical substrate, so the report can
    // state what the scaler bought each tenant (the colocate engine's
    // baseline pattern).  Untraced — the main run owns the recorder —
    // and non-recursive, because the clone's policy is static.
    let baseline = match &spec.replication {
        Some(r) if r.policy == ScalerPolicy::Watermark => {
            let mut solo = spec.clone();
            solo.replication = Some(ReplicationSpec {
                policy: ScalerPolicy::Static,
                ..r.clone()
            });
            let disabled = TraceRecorder::disabled();
            Some(run_traffic(&solo, testbed, &disabled)?)
        }
        _ => None,
    };
    let n = testbed.nodes();
    let mut state = FaultState::for_run(spec, testbed);
    let mut net =
        NetSim::with_capacity(4 * n + 2 * testbed.racks() + 2 * testbed.site_names.len());
    let links = testbed.build_network(&mut net);
    let mut q: EventQueue<Ev> = EventQueue::with_capacity(4096);
    let tracer = rec.tracer("traffic");
    let mut engine = Engine::new(spec, tspec, testbed, &mut net, links.clone(), &state, tracer)?;
    core::schedule_faults(&mut state, &mut q, 0.0);
    engine.schedule_arrivals(&mut q);

    let out = {
        let mut h = TrafficHarness {
            engine: &mut engine,
        };
        let tracer = rec.tracer("traffic");
        core::drive(&mut h, &mut net, &mut q, &mut state, &links, testbed, &tracer)?
    };
    engine.events = out.events;

    let traffic = engine.traffic_report();
    let mut elasticity = engine.elasticity_report(&state);
    if let (Some(e), Some(base)) = (elasticity.as_mut(), baseline.as_ref()) {
        let base_traffic = base.traffic.as_ref().expect("baseline run reports SLOs");
        e.tenant_deltas = traffic
            .tenants
            .iter()
            .zip(&base_traffic.tenants)
            .map(|(m, b)| TenantSloDelta {
                name: m.name.clone(),
                p50_delta_ms: m.p50_ms - b.p50_ms,
                p95_delta_ms: m.p95_ms - b.p95_ms,
                p99_delta_ms: m.p99_ms - b.p99_ms,
            })
            .collect();
    }
    Ok(ScenarioReport {
        name: spec.name.clone(),
        workload: "traffic",
        nodes: testbed.nodes(),
        racks: testbed.racks(),
        sites: testbed.site_names.len(),
        makespan_secs: traffic.makespan_secs,
        events: engine.events,
        segments: engine.completed as usize,
        reassignments: engine.reassignments,
        locality_fraction: traffic.near_fraction,
        shuffle_gbytes: engine.served_bytes / 1e9,
        faults_injected: state.injected,
        nodes_crashed: state.crashes,
        speculative_launched: 0,
        speculative_won: 0,
        traffic: Some(traffic),
        colocation: None,
        comparison: None,
        angle: None,
        elasticity,
        trace_digest: String::new(),
    })
}

// ------------------------------------------------------------ events

/// Service-side events.  The fault plan rides the shared
/// [`FaultEv`] vocabulary, scheduled by `core::schedule_faults` and
/// intercepted by `core::drive`; the engine itself only ever emits the
/// first three variants.
pub(crate) enum Ev {
    /// Open-loop arrival tick: issue one request, schedule the next.
    Arrive,
    /// Closed-loop client finished thinking.
    ClientWake { client: u32 },
    /// Metadata resolved: admit the request at a replica.
    Dispatch { req: u32 },
    /// Periodic elastic-replication evaluation (DESIGN.md §16).  Only
    /// ever scheduled when the `[replication]` policy is non-static,
    /// so static and scaler-off runs share a byte-identical timeline.
    ScalerTick,
    /// Crash / brown-out events owned by `scenario::core`.
    Fault(FaultEv),
}

impl CoreEv for Ev {
    fn from_fault(f: FaultEv) -> Ev {
        Ev::Fault(f)
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            Ev::Fault(f) => Some(*f),
            _ => None,
        }
    }

    fn trace_name(&self) -> &'static str {
        match self {
            Ev::Arrive => "arrive",
            Ev::ClientWake { .. } => "client_wake",
            Ev::Dispatch { .. } => "dispatch",
            Ev::ScalerTick => "scaler_tick",
            Ev::Fault(_) => "fault",
        }
    }
}

/// The standalone traffic driver plugged into the core loop: the
/// engine is the whole workload, with no post-wave hook.
struct TrafficHarness<'e, 'a> {
    engine: &'e mut Engine<'a>,
}

impl<'e, 'a> Harness for TrafficHarness<'e, 'a> {
    type Ev = Ev;

    fn finished(&self, net: &NetSim) -> bool {
        self.engine.done() && net.active_flows() == 0
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.flow_done(fid, now, net, q, state);
        Ok(())
    }

    fn handle(
        &mut self,
        ev: Ev,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.handle_event(ev, now, net, q, state);
        Ok(())
    }

    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.on_crash(node, now, net, q);
        Ok(())
    }

    fn after_wave(
        &mut self,
        _now: f64,
        _drained: bool,
        _net: &mut NetSim,
        _q: &mut EventQueue<Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        Ok(())
    }

    fn gauges(&self) -> HarnessGauges {
        self.engine.gauges()
    }
}

#[derive(Clone, Copy)]
enum FlowKind {
    /// A client-visible request transfer.
    Service { req: u32 },
    /// Background write replication between the recorded endpoints.
    Replicate { src: u32, dst: u32 },
    /// A scaler-ordered replica grow: `file`'s bytes moving from live
    /// holder `src` into arena slot `slot` on the destination node.
    /// The slot is `pending` until the bytes land.
    Rereplicate { file: u32, slot: u8, src: u32, dst: u32 },
}

/// Flow-id-indexed side table for this engine's flows.  Flow ids are
/// issued monotonically by the shared `NetSim`, so a base-offset ring
/// replaces the former `BTreeMap`: O(1) insert/remove, iteration in id
/// order with no hashing or tree rebalancing — the map lookups that
/// dominated the 10^6-request profile.  Holes (`None`) are ids owned by
/// a co-driven engine (colocate) or flows already completed.
#[derive(Default)]
struct FlowTable {
    base: u64,
    slots: VecDeque<Option<FlowKind>>,
    len: usize,
}

impl FlowTable {
    fn insert(&mut self, fid: FlowId, kind: FlowKind) {
        if self.slots.is_empty() {
            self.base = fid.0;
        }
        debug_assert!(fid.0 >= self.base, "flow ids are monotone");
        let idx = (fid.0 - self.base) as usize;
        if idx >= self.slots.len() {
            self.slots.resize(idx + 1, None);
        }
        debug_assert!(self.slots[idx].is_none(), "flow id reused");
        self.slots[idx] = Some(kind);
        self.len += 1;
    }

    fn remove(&mut self, fid: FlowId) -> Option<FlowKind> {
        if fid.0 < self.base {
            return None;
        }
        let idx = (fid.0 - self.base) as usize;
        let kind = self.slots.get_mut(idx)?.take()?;
        self.len -= 1;
        // Advance the base past leading holes so the ring stays sized
        // to the in-flight window, not the run's full flow history.
        while let Some(None) = self.slots.front() {
            self.slots.pop_front();
            self.base += 1;
        }
        self.len_shrink();
        Some(kind)
    }

    /// Trailing holes accumulate when removals hit the back; trim them
    /// so `iter` stays proportional to the window.
    fn len_shrink(&mut self) {
        while let Some(None) = self.slots.back() {
            self.slots.pop_back();
        }
    }

    /// Live (fid, kind) pairs in flow-id order.
    fn iter(&self) -> impl Iterator<Item = (FlowId, &FlowKind)> + '_ {
        self.slots
            .iter()
            .enumerate()
            .filter_map(move |(i, k)| k.as_ref().map(|k| (FlowId(self.base + i as u64), k)))
    }
}

// ------------------------------------------------------------ catalog

/// The object catalog: placement and popularity, fixed at build time.
struct Catalog {
    /// FNV hash of each object's name (the Chord lookup key).
    hash: Vec<u64>,
    primary: Vec<u32>,
    replica: Vec<u32>,
    /// Normalized popularity CDF over key ids (Zipf ranks scattered
    /// over the id space by a seeded shuffle, so hot keys spread
    /// across slaves instead of clustering at id 0).
    cdf: Vec<f64>,
}

impl Catalog {
    fn build(
        files: usize,
        theta: f64,
        nodes: usize,
        testbed: &Testbed,
        rng: &mut Pcg64,
    ) -> Catalog {
        // The replica partner depends only on the primary node:
        // precompute it per node instead of re-deriving it per file.
        let partner: Vec<u32> = (0..nodes)
            .map(|n| rack_diverse_replica(testbed, n) as u32)
            .collect();
        let mut hash = Vec::with_capacity(files);
        let mut primary = Vec::with_capacity(files);
        let mut replica = Vec::with_capacity(files);
        for k in 0..files {
            hash.push(hash_name(&format!("svc/obj{k:08}.dat")));
            let p = rng.gen_range(nodes as u64) as u32;
            primary.push(p);
            replica.push(partner[p as usize]);
        }
        let mut perm: Vec<u32> = (0..files as u32).collect();
        rng.shuffle(&mut perm);
        let mut weight = vec![0.0f64; files];
        for (rank, &key) in perm.iter().enumerate() {
            weight[key as usize] = 1.0 / ((rank + 1) as f64).powf(theta);
        }
        let total: f64 = weight.iter().sum();
        let mut acc = 0.0;
        let mut cdf = Vec::with_capacity(files);
        for w in &weight {
            acc += w / total;
            cdf.push(acc);
        }
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Catalog {
            hash,
            primary,
            replica,
            cdf,
        }
    }

    fn sample_key(&self, rng: &mut Pcg64) -> u32 {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c <= u) as u32
    }
}

// ------------------------------------------------------------ replica sets

const SLOT_EMPTY: u8 = 0;
const SLOT_LIVE: u8 = 1;
/// Grow transfer in flight: the slot is claimed but does not serve.
const SLOT_PENDING: u8 = 2;
/// Shed ordered: out of the read set, data removed once `pinned` = 0.
const SLOT_DRAINING: u8 = 3;

/// Per-file replica sets in one flat arena: `cap` slots per file, laid
/// out file-major so a file's whole set is one cache line at cap <= 8.
/// Replaces the fixed primary/partner pair wherever requests are
/// admitted; the catalog keeps seeding the first two slots so a static
/// run reproduces the pre-elastic placement exactly.
struct ReplicaSets {
    cap: usize,
    /// Node holding each slot (`u32::MAX` when empty).
    nodes: Vec<u32>,
    /// SLOT_* state per slot.
    state: Vec<u8>,
    /// Admitted requests (serving or queued) pinned to each slot: a
    /// draining slot's data is removed only when this reaches zero.
    pinned: Vec<u32>,
    /// Live replicas per file.
    live: Vec<u8>,
    total_live: u64,
}

impl ReplicaSets {
    fn build(catalog: &Catalog, cap: usize) -> ReplicaSets {
        let files = catalog.primary.len();
        let mut sets = ReplicaSets {
            cap,
            nodes: vec![u32::MAX; files * cap],
            state: vec![SLOT_EMPTY; files * cap],
            pinned: vec![0; files * cap],
            live: vec![0; files],
            total_live: 0,
        };
        for f in 0..files {
            let i = f * cap;
            sets.nodes[i] = catalog.primary[f];
            sets.state[i] = SLOT_LIVE;
            sets.live[f] = 1;
            sets.total_live += 1;
            if cap > 1 && catalog.replica[f] != catalog.primary[f] {
                sets.nodes[i + 1] = catalog.replica[f];
                sets.state[i + 1] = SLOT_LIVE;
                sets.live[f] += 1;
                sets.total_live += 1;
            }
        }
        sets
    }

    fn idx(&self, file: u32, slot: usize) -> usize {
        file as usize * self.cap + slot
    }

    /// Live slot nodes in slot order (what admission ranks).
    fn live_nodes_into(&self, file: u32, out: &mut Vec<u32>) {
        out.clear();
        let base = file as usize * self.cap;
        for s in 0..self.cap {
            if self.state[base + s] == SLOT_LIVE {
                out.push(self.nodes[base + s]);
            }
        }
    }

    /// The live slot hosted on `node`, if any.
    fn slot_on(&self, file: u32, node: u32) -> Option<usize> {
        let base = file as usize * self.cap;
        (0..self.cap)
            .find(|&s| self.state[base + s] == SLOT_LIVE && self.nodes[base + s] == node)
    }

    /// Any non-empty slot on `node` (live, pending or draining)?
    fn holds(&self, file: u32, node: u32) -> bool {
        let base = file as usize * self.cap;
        (0..self.cap)
            .any(|s| self.state[base + s] != SLOT_EMPTY && self.nodes[base + s] == node)
    }

    fn first_empty_slot(&self, file: u32) -> Option<usize> {
        let base = file as usize * self.cap;
        (0..self.cap).find(|&s| self.state[base + s] == SLOT_EMPTY)
    }

    fn clear_slot(&mut self, file: u32, slot: usize) {
        let i = self.idx(file, slot);
        if self.state[i] == SLOT_LIVE {
            self.live[file as usize] -= 1;
            self.total_live -= 1;
        }
        self.state[i] = SLOT_EMPTY;
        self.nodes[i] = u32::MAX;
        self.pinned[i] = 0;
    }
}

// ------------------------------------------------------------ sessions

/// Client-session store: dense for closed-loop populations (every
/// client participates), lazy for open-loop ones (only clients the
/// arrival process actually picks get a session).
enum Sessions {
    Dense(Vec<ClientSession>),
    Sparse(BTreeMap<u32, ClientSession>),
}

impl Sessions {
    fn get_or_create(&mut self, id: u32, node: u32) -> &mut ClientSession {
        match self {
            Sessions::Dense(v) => &mut v[id as usize],
            Sessions::Sparse(m) => m
                .entry(id)
                .or_insert_with(|| ClientSession::new(id, node)),
        }
    }
}

// ------------------------------------------------------------ requests

struct Request {
    client: u32,
    tenant: u16,
    key: u32,
    write: bool,
    arrived: f64,
    /// Latency components not simulated as events (connection setup).
    overhead: f64,
    /// Slave currently serving or queueing this request.
    slave: u32,
    /// Replica-arena slot the request is pinned to while admitted
    /// (serving or queued); keeps a draining replica's data alive
    /// until the request completes.  `u8::MAX` = not pinned.
    slot: u8,
    attempts: u8,
    /// Served same-node or same-rack (set at service start).
    near: bool,
    /// Lookup missed: fill the session's metadata cache when the
    /// resolution completes (at dispatch), not at issue — a concurrent
    /// request for the same key must not hit metadata still in flight.
    fill_meta: bool,
}

struct SlaveState {
    active: usize,
    /// Per-tenant admission queues, drained priority-class by
    /// priority-class (ascending), round-robin within a class.
    queues: Vec<VecDeque<u32>>,
    queued: usize,
    /// Round-robin pointer per priority class.
    rr: Vec<usize>,
}

// ------------------------------------------------------------ engine

/// The traffic engine's state.  Borrows its substrate (network, event
/// queue, fault state) per call so a driving loop can share that
/// substrate with other workloads; fields the colocation driver reads
/// for its joint report are `pub(crate)`.
pub(crate) struct Engine<'a> {
    tspec: &'a TrafficSpec,
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    models: TransportModels,
    links: NetLinks,
    /// One link per node modelling its read/write spindle: concurrent
    /// service slots share the disk via max-min fairness, and a
    /// straggler is simply a slower disk link.  Shared with the batch
    /// job's segment I/O in colocated runs.
    pub(crate) disk_read: Vec<LinkId>,
    pub(crate) disk_write: Vec<LinkId>,
    /// Nominal link capacities (rate caps are computed against these so
    /// a degradation window squeezes flows through the shared link and
    /// lifts when it ends).
    pub(crate) nominal_caps: Vec<f64>,
    /// Observability feed: admission verdicts and cancelled transfers
    /// go straight to the run's trace recorder (cheap no-ops when
    /// capture is off — the digest still folds them in).
    tracer: Tracer,
    ring: ChordRing,
    ring_ids: Vec<u64>,
    ring_to_node: BTreeMap<u64, u32>,
    catalog: Catalog,
    sets: ReplicaSets,
    sessions: Sessions,
    conn: ConnectionCache,
    rng: Pcg64,
    seed: u64,
    mean_rtt: f64,
    requests: Vec<Request>,
    slaves: Vec<SlaveState>,
    flows: FlowTable,
    /// Tenant indices grouped by ascending priority class (the drain
    /// order at every slave); one entry per distinct priority.
    priority_classes: Vec<Vec<usize>>,
    // ---- elastic replication (None = static pair, no scaler)
    rspec: Option<&'a ReplicationSpec>,
    scaler: Option<Box<dyn Scaler>>,
    /// Reads per file over the current scaler window.
    window_reads: Vec<u32>,
    /// Mix-weighted mean object size: what one re-replication moves.
    mean_object_bytes: f64,
    grows: u64,
    sheds: u64,
    drained_sheds: u64,
    rerep_tier: TierBytes,
    peak_replicas: u64,
    timeline: Vec<(f64, u64)>,
    invariant_violations: u64,
    // ---- counters
    issued: u64,
    outstanding: u64,
    pub(crate) completed: u64,
    rejected: u64,
    unavailable: u64,
    pub(crate) events: u64,
    pub(crate) reassignments: u64,
    near_served: u64,
    meta_hits: u64,
    meta_misses: u64,
    pub(crate) served_bytes: f64,
    replica_bytes: f64,
    peak_queue: usize,
    makespan: f64,
    // ---- per tenant
    t_requests: Vec<u64>,
    t_completed: Vec<u64>,
    t_rejected: Vec<u64>,
    t_unavailable: Vec<u64>,
    t_bytes: Vec<f64>,
    t_lat_ms: Vec<Vec<f64>>,
    tenant_cdf: Vec<f64>,
}

impl<'a> Engine<'a> {
    /// Build the engine against an externally-owned network that
    /// already carries the topology links (`links`).  Adds the
    /// per-node disk links to `net`; `state` supplies the static
    /// straggler factors baked into those disk capacities.
    pub(crate) fn new(
        spec: &'a ScenarioSpec,
        tspec: &'a TrafficSpec,
        testbed: &'a Testbed,
        net: &mut NetSim,
        links: NetLinks,
        state: &FaultState,
        tracer: Tracer,
    ) -> Result<Engine<'a>, String> {
        let cfg = &spec.cfg;
        let n = testbed.nodes();
        let mut rng = Pcg64::new(cfg.seed);
        let mut ring_rng = rng.fork(1);
        let mut catalog_rng = rng.fork(2);
        let traffic_rng = rng.fork(3);

        let ring_ids: Vec<u64> = (0..n).map(|_| ring_rng.next_u64()).collect();
        let ring = ChordRing::build(&ring_ids);
        let ring_to_node: BTreeMap<u64, u32> = ring_ids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i as u32))
            .collect();
        let catalog = Catalog::build(tspec.files, tspec.zipf_theta, n, testbed, &mut catalog_rng);

        // Disk links: one read and one write spindle link per node
        // (straggler factors are static, so they bake into the disk
        // capacity).
        let read_eff = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
        let write_eff = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
        let disk_read: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((read_eff * state.factor[i]).max(1.0)))
            .collect();
        let disk_write: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((write_eff * state.factor[i]).max(1.0)))
            .collect();
        let nominal_caps: Vec<f64> = (0..net.link_count())
            .map(|i| net.link_capacity(LinkId(i)))
            .collect();

        let mut acc = 0.0;
        for a in 0..n {
            for b in 0..n {
                acc += testbed.rtt_secs(a, b);
            }
        }
        let mean_rtt = acc / (n * n).max(1) as f64;

        let tenants = tspec.tenants.len();
        let total_weight: f64 = tspec.tenants.iter().map(|t| t.weight).sum();
        let mut tenant_cdf = Vec::with_capacity(tenants);
        let mut tacc = 0.0;
        for t in &tspec.tenants {
            tacc += t.weight / total_weight;
            tenant_cdf.push(tacc);
        }
        if let Some(last) = tenant_cdf.last_mut() {
            *last = 1.0;
        }

        let sessions = match tspec.arrival {
            ArrivalProcess::Closed { .. } => {
                let mut v = Vec::with_capacity(tspec.clients);
                for id in 0..tspec.clients as u32 {
                    v.push(ClientSession::new(id, client_node(cfg.seed, id, n)));
                }
                Sessions::Dense(v)
            }
            ArrivalProcess::Open { .. } => Sessions::Sparse(BTreeMap::new()),
        };

        // Tenants grouped by ascending priority class, stable within a
        // class (tenant order = parse order, already name-sorted).
        let mut prios: Vec<u8> = tspec.tenants.iter().map(|t| t.priority).collect();
        prios.sort_unstable();
        prios.dedup();
        let priority_classes: Vec<Vec<usize>> = prios
            .iter()
            .map(|&p| {
                (0..tenants)
                    .filter(|&i| tspec.tenants[i].priority == p)
                    .collect()
            })
            .collect();

        let slaves = (0..n)
            .map(|_| SlaveState {
                active: 0,
                queues: (0..tenants).map(|_| VecDeque::new()).collect(),
                queued: 0,
                rr: vec![0; priority_classes.len()],
            })
            .collect();

        let rspec = spec.replication.as_ref();
        if let Some(r) = rspec {
            r.validate()?;
        }
        // Replica arena: static pairs without a [replication] block.
        let cap = rspec.map_or(2, |r| r.max_replicas as usize).max(2);
        let sets = ReplicaSets::build(&catalog, cap);
        let total_live = sets.total_live;
        let mean_object_bytes = tspec
            .tenants
            .iter()
            .map(|t| t.weight / total_weight * t.object_bytes)
            .sum::<f64>()
            .max(1.0);

        Ok(Engine {
            tspec,
            testbed,
            cfg,
            models: TransportModels::default(),
            links,
            disk_read,
            disk_write,
            nominal_caps,
            tracer,
            ring,
            ring_ids,
            ring_to_node,
            catalog,
            sets,
            sessions,
            conn: ConnectionCache::new(
                cfg.service.conn_cache_entries,
                cfg.service.conn_idle_secs,
            ),
            rng: traffic_rng,
            seed: cfg.seed,
            mean_rtt,
            requests: Vec::with_capacity(tspec.requests.min(1 << 22) as usize),
            slaves,
            flows: FlowTable::default(),
            priority_classes,
            rspec,
            scaler: rspec.map(|r| r.scaler()),
            window_reads: vec![0; tspec.files],
            mean_object_bytes,
            grows: 0,
            sheds: 0,
            drained_sheds: 0,
            rerep_tier: TierBytes::default(),
            peak_replicas: total_live,
            timeline: vec![(0.0, total_live)],
            invariant_violations: 0,
            issued: 0,
            outstanding: 0,
            completed: 0,
            rejected: 0,
            unavailable: 0,
            events: 0,
            reassignments: 0,
            near_served: 0,
            meta_hits: 0,
            meta_misses: 0,
            served_bytes: 0.0,
            replica_bytes: 0.0,
            peak_queue: 0,
            makespan: 0.0,
            t_requests: vec![0; tenants],
            t_completed: vec![0; tenants],
            t_rejected: vec![0; tenants],
            t_unavailable: vec![0; tenants],
            t_bytes: vec![0.0; tenants],
            t_lat_ms: (0..tenants).map(|_| Vec::new()).collect(),
            tenant_cdf,
        })
    }

    // ---------------------------------------------------- scheduling

    pub(crate) fn schedule_arrivals<E: From<Ev>>(&mut self, q: &mut EventQueue<E>) {
        match self.tspec.arrival {
            ArrivalProcess::Open { rps } => {
                let dt = self.rng.next_exp(rps * self.tspec.shape.rate_factor(0.0));
                q.push_at(dt, Ev::Arrive.into());
            }
            ArrivalProcess::Closed { think_secs } => {
                for client in 0..self.tspec.clients as u32 {
                    let dt = if think_secs > 0.0 {
                        self.rng.next_exp(1.0 / think_secs)
                    } else {
                        0.0
                    };
                    q.push_at(dt, Ev::ClientWake { client }.into());
                }
            }
        }
        // Static policies never tick: their event timeline must equal a
        // run with no [replication] block at all, byte for byte.
        if let Some(r) = self.rspec {
            if r.policy != ScalerPolicy::Static {
                q.push_at(r.interval_secs, Ev::ScalerTick.into());
            }
        }
    }

    /// All requests issued and none outstanding (flows are the driving
    /// loop's to check — it owns the network).
    pub(crate) fn done(&self) -> bool {
        self.issued >= self.tspec.requests && self.outstanding == 0
    }

    // ---------------------------------------------------- request intake

    /// Weighted tenant pick from the engine's main stream (open loop:
    /// the mix is a property of the aggregate arrival process).
    fn sample_tenant(&mut self) -> u16 {
        let u = self.rng.next_f64();
        self.tenant_cdf.partition_point(|&c| c <= u) as u16
    }

    /// Closed loop: a client belongs to one tenant for its whole life,
    /// picked from a per-client hash stream so the assignment does not
    /// depend on arrival interleaving.
    fn tenant_of_client(&self, client: u32) -> u16 {
        let mut sm = SplitMix64::new(self.seed.rotate_left(17) ^ client as u64);
        let u = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.tenant_cdf.partition_point(|&c| c <= u) as u16
    }

    fn issue_request<E: From<Ev>>(
        &mut self,
        client: u32,
        tenant: u16,
        now: f64,
        state: &FaultState,
        q: &mut EventQueue<E>,
    ) {
        let key = self.catalog.sample_key(&mut self.rng);
        let write = self.rng.next_f64() < self.tspec.tenants[tenant as usize].write_fraction;
        if !write {
            // Demand feed for the scaler window (reads only: writes pin
            // to one copy regardless of replica count).
            self.window_reads[key as usize] += 1;
        }
        let lookup_secs = self.resolve_meta(client, key, now, state);
        let req = self.requests.len() as u32;
        self.requests.push(Request {
            client,
            tenant,
            key,
            write,
            arrived: now,
            overhead: 0.0,
            slave: u32::MAX,
            slot: u8::MAX,
            attempts: 0,
            near: false,
            fill_meta: lookup_secs > 0.0,
        });
        self.issued += 1;
        self.outstanding += 1;
        self.t_requests[tenant as usize] += 1;
        q.push_at(now + lookup_secs, Ev::Dispatch { req }.into());
    }

    /// §4 step 2: resolve the object's locations — from the session's
    /// metadata cache when fresh, else through the Chord ring.  Returns
    /// the lookup latency.
    fn resolve_meta(&mut self, client: u32, key: u32, now: f64, state: &FaultState) -> f64 {
        let n = self.testbed.nodes();
        let node = client_node(self.seed, client, n);
        let (home, hit) = {
            let s = self.sessions.get_or_create(client, node);
            (s.node as usize, s.meta_lookup(key as u64, now))
        };
        if hit {
            self.meta_hits += 1;
            return 0.0;
        }
        self.meta_misses += 1;
        // A crashed home node's clients re-enter the overlay through
        // the first live node.
        let start = if state.dead[home] {
            *state.alive().first().unwrap_or(&home)
        } else {
            home
        };
        let (owner_id, hops) = self
            .ring
            .lookup(self.ring_ids[start], self.catalog.hash[key as usize])
            .expect("non-empty ring");
        let owner = self.ring_to_node[&owner_id] as usize;
        // The cache entry is written when the resolution lands
        // (dispatch time), via Request::fill_meta — not here.
        hops as f64 * self.mean_rtt + self.testbed.rtt_secs(home, owner)
    }

    // ---------------------------------------------------- admission

    /// Live candidate slaves for a request, in the client's preference
    /// order.  Candidates come from the replica arena: pending copies
    /// are still transferring and draining copies have left the read
    /// set.  Writes pin to the first live copy (the primary while it
    /// lives); reads take any live copy, ranked by proximity.
    fn candidates(&self, req: u32, state: &FaultState) -> Vec<u32> {
        let r = &self.requests[req as usize];
        let mut cands = Vec::with_capacity(self.sets.cap);
        self.sets.live_nodes_into(r.key, &mut cands);
        cands.retain(|&c| !state.dead[c as usize]);
        if r.write {
            cands.truncate(1);
            return cands;
        }
        let home = client_node(self.seed, r.client, self.testbed.nodes()) as usize;
        rank_replicas(self.testbed, home, &mut cands);
        cands
    }

    fn dispatch<E: From<Ev>>(
        &mut self,
        req: u32,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) {
        // A missed lookup has now resolved: fill the session's
        // metadata cache, TTL clocked from the resolution.
        if self.requests[req as usize].fill_meta {
            self.requests[req as usize].fill_meta = false;
            let (client, key) = {
                let r = &self.requests[req as usize];
                (r.client, r.key)
            };
            let node = client_node(self.seed, client, self.testbed.nodes());
            let ttl = self.cfg.service.meta_ttl_secs;
            let cap = self.cfg.service.meta_cache_entries;
            self.sessions
                .get_or_create(client, node)
                .meta_insert(key as u64, now + ttl, cap);
        }
        let cands = self.candidates(req, state);
        if cands.is_empty() || self.requests[req as usize].attempts >= MAX_ATTEMPTS {
            self.trace_admission(req, now, "unavailable", -1);
            self.finish_non_served(req, now, false, q);
            return;
        }
        self.requests[req as usize].attempts += 1;
        let slots = self.cfg.service.slots_per_slave.max(1);
        // Pass 1: an idle slot anywhere beats queueing at the nearest.
        for &cand in &cands {
            if self.slaves[cand as usize].active < slots {
                self.trace_admission(req, now, "served", cand as i64);
                self.pin(req, cand);
                self.start_service(req, cand, now, net);
                return;
            }
        }
        // Pass 2: queue room, in preference order.
        let tenant = self.requests[req as usize].tenant as usize;
        for &cand in &cands {
            if self.slaves[cand as usize].queued < self.cfg.service.queue_capacity {
                self.trace_admission(req, now, "queued", cand as i64);
                self.pin(req, cand);
                let ss = &mut self.slaves[cand as usize];
                ss.queues[tenant].push_back(req);
                ss.queued += 1;
                self.peak_queue = self.peak_queue.max(ss.queued);
                self.requests[req as usize].slave = cand;
                return;
            }
        }
        // Every live replica saturated: shed the request.
        self.trace_admission(req, now, "rejected", cands[0] as i64);
        self.finish_non_served(req, now, true, q);
    }

    /// Emit the admission verdict for `req` into the trace, tagged with
    /// the tenant and the slave that took (or shed) it.
    fn trace_admission(&self, req: u32, now: f64, verdict: &'static str, node: i64) {
        let tenant = self.requests[req as usize].tenant as usize;
        self.tracer
            .admission(now, verdict, node, &self.tspec.tenants[tenant].name);
    }

    /// Pin an admitted request to the replica slot that will serve it:
    /// a draining slot's data survives until every pin is released.
    fn pin(&mut self, req: u32, slave: u32) {
        let key = self.requests[req as usize].key;
        match self.sets.slot_on(key, slave) {
            Some(slot) => {
                let i = self.sets.idx(key, slot);
                self.sets.pinned[i] += 1;
                self.requests[req as usize].slot = slot as u8;
            }
            None => {
                // Admission only offers live slots; missing one is a bug.
                self.invariant_violations += 1;
                self.requests[req as usize].slot = u8::MAX;
            }
        }
    }

    /// Release a completed request's pin; a draining slot whose last
    /// pin leaves is removed here (the deferred half of a shed).
    fn unpin(&mut self, req: u32) {
        let (key, slave, slot) = {
            let r = &self.requests[req as usize];
            (r.key, r.slave, r.slot)
        };
        if slot == u8::MAX {
            return;
        }
        let i = self.sets.idx(key, slot as usize);
        if self.sets.state[i] == SLOT_EMPTY
            || self.sets.nodes[i] != slave
            || self.sets.pinned[i] == 0
        {
            self.invariant_violations += 1;
            return;
        }
        self.sets.pinned[i] -= 1;
        if self.sets.state[i] == SLOT_DRAINING && self.sets.pinned[i] == 0 {
            self.sets.clear_slot(key, slot as usize);
            self.drained_sheds += 1;
        }
    }

    /// Terminal non-success: `rejected` (admission shed) or
    /// `unavailable` (no live replica / retries exhausted).
    fn finish_non_served<E: From<Ev>>(
        &mut self,
        req: u32,
        now: f64,
        is_rejection: bool,
        q: &mut EventQueue<E>,
    ) {
        let tenant = self.requests[req as usize].tenant as usize;
        if is_rejection {
            self.rejected += 1;
            self.t_rejected[tenant] += 1;
        } else {
            self.unavailable += 1;
            self.t_unavailable[tenant] += 1;
        }
        self.outstanding -= 1;
        self.makespan = self.makespan.max(now);
        let client = self.requests[req as usize].client;
        self.client_think(client, now, q);
    }

    /// Closed loop only: schedule the client's next cycle.
    fn client_think<E: From<Ev>>(&mut self, client: u32, now: f64, q: &mut EventQueue<E>) {
        if let ArrivalProcess::Closed { think_secs } = self.tspec.arrival {
            let dt = if think_secs > 0.0 {
                self.rng.next_exp(1.0 / think_secs)
            } else {
                0.0
            };
            q.push_at(now + dt, Ev::ClientWake { client }.into());
        }
    }

    /// Start a byte transfer from `from` to `to`: the network route
    /// between them, plus the reading/writing disk links of whichever
    /// ends touch a spindle.  The rate cap comes from the transport
    /// protocol against NOMINAL link rates (degradation constrains the
    /// shared links instead, so it lifts when the window ends).
    #[allow(clippy::too_many_arguments)]
    fn start_transfer(
        &mut self,
        from: usize,
        to: usize,
        bytes: f64,
        read_disk: Option<usize>,
        write_disk: Option<usize>,
        kind: FlowKind,
        net: &mut NetSim,
    ) {
        let net_path = self.testbed.path(&self.links, from, to);
        let bottleneck = net_path
            .iter()
            .map(|l| self.nominal_caps[l.0])
            .fold(f64::INFINITY, f64::min)
            .min(self.testbed.nic_bps);
        let rtt = self.testbed.rtt_secs(from, to);
        let proto_cap = match self.cfg.sphere_transport {
            TransportKind::Udt => udt_efficiency(self.models.udt.efficiency, rtt) * bottleneck,
            TransportKind::Tcp => self.models.tcp.rate_cap(bottleneck, rtt),
        };
        let mut path = Vec::with_capacity(net_path.len() + 2);
        if let Some(node) = read_disk {
            path.push(self.disk_read[node]);
        }
        path.extend_from_slice(&net_path);
        if let Some(node) = write_disk {
            path.push(self.disk_write[node]);
        }
        let fid = net.start_flow(&path, bytes.max(1.0), proto_cap.max(1.0));
        self.flows.insert(fid, kind);
    }

    fn start_service(&mut self, req: u32, slave: u32, now: f64, net: &mut NetSim) {
        let n = self.testbed.nodes();
        let (write, tenant, client) = {
            let r = &self.requests[req as usize];
            (r.write, r.tenant as usize, r.client)
        };
        let home = client_node(self.seed, client, n) as usize;
        let s = slave as usize;
        self.slaves[s].active += 1;

        // §4 connection cache: one handshake RTT on a miss, free reuse
        // on a hit.  Keyed by the (server, client-edge) node pair.
        let rtt = self.testbed.rtt_secs(s, home);
        let (a, b) = if write {
            (home as u32, slave)
        } else {
            (slave, home as u32)
        };
        let cached = self.conn.acquire(now, a, b);
        let setup = if cached { 0.0 } else { rtt };

        let bytes = self.tspec.tenants[tenant].object_bytes;
        if write {
            self.start_transfer(home, s, bytes, None, Some(s), FlowKind::Service { req }, net);
        } else {
            self.start_transfer(s, home, bytes, Some(s), None, FlowKind::Service { req }, net);
        }

        let r = &mut self.requests[req as usize];
        r.slave = slave;
        r.overhead += setup;
        r.near = self.testbed.proximity(s, home) <= Proximity::SameRack;
    }

    /// A slot freed at `slave`: serve the next queued request.  Lower
    /// priority classes drain first; within a class, round-robin across
    /// tenants so equals share fairly (a single class reproduces the
    /// old all-tenant round-robin exactly).
    fn dequeue_next(&mut self, slave: u32, now: f64, net: &mut NetSim) {
        let slots = self.cfg.service.slots_per_slave.max(1);
        let s = slave as usize;
        if self.slaves[s].active >= slots || self.slaves[s].queued == 0 {
            return;
        }
        for ci in 0..self.priority_classes.len() {
            let len = self.priority_classes[ci].len();
            for i in 1..=len {
                let pos = (self.slaves[s].rr[ci] + i) % len;
                let idx = self.priority_classes[ci][pos];
                if let Some(req) = self.slaves[s].queues[idx].pop_front() {
                    self.slaves[s].rr[ci] = pos;
                    self.slaves[s].queued -= 1;
                    self.start_service(req, slave, now, net);
                    return;
                }
            }
        }
    }

    // ---------------------------------------------------- completion

    /// A network flow landed.  Returns `true` when the flow belonged to
    /// this engine (so a colocated driver can offer each completion to
    /// both sides and count it once).
    pub(crate) fn flow_done<E: From<Ev>>(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) -> bool {
        let Some(kind) = self.flows.remove(fid) else {
            return false;
        };
        let req = match kind {
            FlowKind::Service { req } => req,
            FlowKind::Replicate { .. } => {
                return true; // background write copy landed; bytes already counted
            }
            FlowKind::Rereplicate { file, slot, src: _, dst } => {
                // The grow transfer landed: the new copy enters the
                // read set (unless its host died mid-transfer — the
                // crash path cancels those flows, so reaching here with
                // a dead host is an accounting bug, not a race).
                let i = self.sets.idx(file, slot as usize);
                if self.sets.state[i] == SLOT_PENDING && self.sets.nodes[i] == dst {
                    self.sets.state[i] = SLOT_LIVE;
                    self.sets.live[file as usize] += 1;
                    self.sets.total_live += 1;
                } else {
                    self.invariant_violations += 1;
                }
                return true;
            }
        };
        let (slave, tenant, write, key, near, latency_ms, client) = {
            let r = &self.requests[req as usize];
            (
                r.slave,
                r.tenant as usize,
                r.write,
                r.key,
                r.near,
                (now - r.arrived + r.overhead) * 1e3,
                r.client,
            )
        };
        self.slaves[slave as usize].active -= 1;
        self.completed += 1;
        self.outstanding -= 1;
        self.t_completed[tenant] += 1;
        let bytes = self.tspec.tenants[tenant].object_bytes;
        self.t_bytes[tenant] += bytes;
        self.served_bytes += bytes;
        self.t_lat_ms[tenant].push(latency_ms);
        self.near_served += near as u64;
        self.makespan = self.makespan.max(now);
        self.unpin(req);

        // A completed write replicates to every other live copy in the
        // background (paper §4: replicas restored to target count; with
        // a static pair this is exactly the old primary<->partner copy).
        if write {
            let src = slave as usize;
            let base = key as usize * self.sets.cap;
            for s in 0..self.sets.cap {
                if self.sets.state[base + s] != SLOT_LIVE {
                    continue;
                }
                let dst = self.sets.nodes[base + s] as usize;
                if dst == src || state.dead[dst] {
                    continue;
                }
                self.start_transfer(
                    src,
                    dst,
                    bytes,
                    Some(src),
                    Some(dst),
                    FlowKind::Replicate {
                        src: src as u32,
                        dst: dst as u32,
                    },
                    net,
                );
                self.replica_bytes += bytes;
            }
        }

        self.dequeue_next(slave, now, net);
        self.client_think(client, now, q);
        true
    }

    // ---------------------------------------------------- faults

    /// React to a crash the driving loop already applied to the shared
    /// `FaultState`: drop the node from the overlay, cancel its
    /// transfers and re-dispatch its requests.
    pub(crate) fn on_crash<E: From<Ev>>(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
    ) {
        // The overlay drops the node: later lookups route to its
        // successor (metadata is replicated there in deployed Sector).
        self.ring.leave(self.ring_ids[node]);

        // Cancel transfers served by the dead slave and re-dispatch
        // their requests; background replications touching it are
        // simply dropped (the copy is lost with the node), and grow
        // transfers from or to it abort — the claimed slot reopens.
        enum Doom {
            Redispatch(u32),
            Drop,
            AbortGrow { file: u32, slot: u8 },
        }
        let doomed: Vec<(FlowId, Doom)> = self
            .flows
            .iter()
            .filter_map(|(fid, kind)| match *kind {
                FlowKind::Service { req }
                    if self.requests[req as usize].slave as usize == node =>
                {
                    Some((fid, Doom::Redispatch(req)))
                }
                FlowKind::Replicate { src, dst }
                    if src as usize == node || dst as usize == node =>
                {
                    Some((fid, Doom::Drop))
                }
                FlowKind::Rereplicate { file, slot, src, dst }
                    if src as usize == node || dst as usize == node =>
                {
                    Some((fid, Doom::AbortGrow { file, slot }))
                }
                _ => None,
            })
            .collect();
        for (fid, doom) in doomed {
            self.flows.remove(fid);
            net.cancel_flow(fid);
            self.tracer.flow_cancel(fid, now);
            match doom {
                Doom::Redispatch(req) => {
                    self.reassignments += 1;
                    q.push_at(now, Ev::Dispatch { req }.into());
                }
                Doom::Drop => {}
                Doom::AbortGrow { file, slot } => self.sets.clear_slot(file, slot as usize),
            }
        }
        // Every replica slot on the dead node empties: the copies are
        // gone with the machine.  No automatic restore — that is the
        // scaler's job (or nobody's, under static replication, exactly
        // like the pre-elastic pair).
        for file in 0..self.window_reads.len() as u32 {
            let base = file as usize * self.sets.cap;
            for s in 0..self.sets.cap {
                if self.sets.state[base + s] != SLOT_EMPTY
                    && self.sets.nodes[base + s] as usize == node
                {
                    self.sets.clear_slot(file, s);
                }
            }
        }
        // Re-dispatch everything queued at the dead slave.
        let tenants = self.slaves[node].queues.len();
        for tq in 0..tenants {
            while let Some(req) = self.slaves[node].queues[tq].pop_front() {
                self.reassignments += 1;
                q.push_at(now, Ev::Dispatch { req }.into());
            }
        }
        self.slaves[node].queued = 0;
        self.slaves[node].active = 0;
    }

    // ---------------------------------------------------- event entry

    /// Handle one service-side event.  Fault events are the driving
    /// loop's responsibility (it owns the `FaultState` and the shared
    /// links) and are ignored here.
    pub(crate) fn handle_event<E: From<Ev>>(
        &mut self,
        ev: Ev,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) {
        let total = self.tspec.requests;
        match ev {
            Ev::Arrive => {
                if self.issued < total {
                    let tenant = self.sample_tenant();
                    let client = self.rng.gen_range(self.tspec.clients as u64) as u32;
                    self.issue_request(client, tenant, now, state, q);
                    if let ArrivalProcess::Open { rps } = self.tspec.arrival {
                        let dt = self.rng.next_exp(rps * self.tspec.shape.rate_factor(now));
                        q.push_at(now + dt, Ev::Arrive.into());
                    }
                }
            }
            Ev::ClientWake { client } => {
                if self.issued < total {
                    let tenant = self.tenant_of_client(client);
                    self.issue_request(client, tenant, now, state, q);
                }
            }
            Ev::Dispatch { req } => self.dispatch(req, now, net, q, state),
            Ev::ScalerTick => self.scaler_tick(now, net, q, state),
            Ev::Fault(_) => {}
        }
    }

    // ---------------------------------------------------- elastic scaling

    /// One scaler window closed: feed the window's per-file demand to
    /// the policy, apply its directives, reschedule.  The tick chain
    /// ends once the arrival stream is exhausted, so the run still
    /// terminates when the queue and network drain.
    fn scaler_tick<E: From<Ev>>(
        &mut self,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) {
        let Some(r) = self.rspec else { return };
        let bounds = r.bounds();
        // Demand snapshot: every file that saw reads this window, plus
        // every file still holding more than the floor (shed candidates
        // even at zero demand).
        let mut loads = Vec::new();
        for (f, &reads) in self.window_reads.iter().enumerate() {
            let live = self.sets.live[f] as u32;
            if live > bounds.max {
                self.invariant_violations += 1;
            }
            if live == 0 || (reads == 0 && live <= bounds.min) {
                continue;
            }
            loads.push(FileLoad {
                file: f as u32,
                replicas: live,
                reads_per_sec_per_replica: reads as f64 / r.interval_secs / live as f64,
            });
        }
        let directives = match self.scaler.as_mut() {
            Some(s) => s.scale(now, &loads, bounds),
            None => Vec::new(),
        };
        if !directives.is_empty() {
            self.tracer.instant(now, "scaler", "directives");
        }
        // One network census per tick steers grow placement toward
        // quiet NICs; directives within the tick share it.
        let flows_per_link = net.link_flow_counts();
        for d in directives {
            match d {
                ReplicaDirective::Grow { file } => {
                    self.apply_grow(file, bounds.max, &flows_per_link, state, net)
                }
                ReplicaDirective::Shed { file } => self.apply_shed(file, bounds.min),
            }
        }
        for w in &mut self.window_reads {
            *w = 0;
        }
        self.peak_replicas = self.peak_replicas.max(self.sets.total_live);
        if self.timeline.len() < TIMELINE_CAP {
            self.timeline.push((now, self.sets.total_live));
        }
        self.tracer.sample(now, "replicas", self.sets.total_live as f64);
        if self.issued < self.tspec.requests {
            q.push_at(now + r.interval_secs, Ev::ScalerTick.into());
        }
    }

    /// Grow one replica of `file`: claim an empty slot, pick the
    /// least-pinned live holder as the source and the quietest
    /// non-holding live node as the destination, and put the bytes on
    /// the shared network.  The copy serves once the transfer lands.
    fn apply_grow(
        &mut self,
        file: u32,
        max: u32,
        flows_per_link: &[usize],
        state: &FaultState,
        net: &mut NetSim,
    ) {
        // Re-checked here (not only in the policy): pending grows from
        // earlier ticks count against the cap through slot occupancy.
        if (self.sets.live[file as usize] as u32) >= max {
            return;
        }
        let Some(slot) = self.sets.first_empty_slot(file) else { return };
        let base = file as usize * self.sets.cap;
        // Source: the live copy with the fewest admitted requests.
        let mut src: Option<(u32, usize)> = None;
        for s in 0..self.sets.cap {
            if self.sets.state[base + s] == SLOT_LIVE {
                let p = self.sets.pinned[base + s];
                if src.map_or(true, |(bp, _)| p < bp) {
                    src = Some((p, self.sets.nodes[base + s] as usize));
                }
            }
        }
        let Some((_, src)) = src else { return };
        // Destination: lowest (rack-already-covered, load, id) among
        // live nodes not already holding the file — rack diversity
        // first, then admission load plus NIC flow count, then id for
        // a total deterministic order.
        let covered_racks: Vec<usize> = (0..self.sets.cap)
            .filter(|&s| self.sets.state[base + s] != SLOT_EMPTY)
            .map(|s| self.testbed.node_rack[self.sets.nodes[base + s] as usize])
            .collect();
        let mut dst: Option<(u64, usize)> = None;
        for n in state.alive() {
            let n = *n;
            if self.sets.holds(file, n as u32) {
                continue;
            }
            let rack_covered = covered_racks.contains(&self.testbed.node_rack[n]) as u64;
            let load = (self.slaves[n].active + self.slaves[n].queued) as u64
                + flows_per_link[self.links.node_up[n].0] as u64;
            let score = (rack_covered << 62) | (load.min(1 << 31) << 30) | n as u64;
            if dst.map_or(true, |(best, _)| score < best) {
                dst = Some((score, n));
            }
        }
        let Some((_, dst)) = dst else { return };
        let bytes = self.mean_object_bytes;
        self.sets.nodes[base + slot] = dst as u32;
        self.sets.state[base + slot] = SLOT_PENDING;
        self.sets.pinned[base + slot] = 0;
        self.rerep_tier.add(self.testbed, src, dst, bytes);
        self.start_transfer(
            src,
            dst,
            bytes,
            Some(src),
            Some(dst),
            FlowKind::Rereplicate {
                file,
                slot: slot as u8,
                src: src as u32,
                dst: dst as u32,
            },
            net,
        );
        self.grows += 1;
    }

    /// Shed one replica of `file`: the highest live slot leaves the
    /// read set immediately; its data is removed now if nothing is
    /// pinned to it, else when the last pinned request completes.
    fn apply_shed(&mut self, file: u32, min: u32) {
        if (self.sets.live[file as usize] as u32) <= min {
            return;
        }
        let base = file as usize * self.sets.cap;
        let Some(slot) = (0..self.sets.cap)
            .rev()
            .find(|&s| self.sets.state[base + s] == SLOT_LIVE)
        else {
            return;
        };
        self.sets.live[file as usize] -= 1;
        self.sets.total_live -= 1;
        self.sheds += 1;
        if self.sets.pinned[base + slot] == 0 {
            self.sets.state[base + slot] = SLOT_EMPTY;
            self.sets.nodes[base + slot] = u32::MAX;
        } else {
            self.sets.state[base + slot] = SLOT_DRAINING;
        }
    }

    /// Elasticity summary (None without a `[replication]` block); the
    /// caller fills in the baseline SLO deltas.
    pub(crate) fn elasticity_report(&mut self, state: &FaultState) -> Option<ElasticityReport> {
        let r = self.rspec?;
        // End-of-run sweep: no copy may survive on a crashed node, and
        // no file may exceed the ceiling.
        for (f, &live) in self.sets.live.iter().enumerate() {
            if live as u32 > r.max_replicas {
                self.invariant_violations += 1;
            }
            let base = f * self.sets.cap;
            for s in 0..self.sets.cap {
                if self.sets.state[base + s] != SLOT_EMPTY
                    && state.dead[self.sets.nodes[base + s] as usize]
                {
                    self.invariant_violations += 1;
                }
            }
        }
        Some(ElasticityReport {
            policy: r.policy.name(),
            grows: self.grows,
            sheds: self.sheds,
            drained_sheds: self.drained_sheds,
            rereplication: self.rerep_tier,
            peak_replicas: self.peak_replicas.max(self.sets.total_live),
            final_replicas: self.sets.total_live,
            replica_timeline: std::mem::take(&mut self.timeline),
            invariant_violations: self.invariant_violations,
            tenant_deltas: Vec::new(),
        })
    }

    /// Scheduler-occupancy gauges for the trace sampler.
    pub(crate) fn gauges(&self) -> HarnessGauges {
        HarnessGauges {
            occupancy: self.slaves.iter().map(|s| s.active as u64).sum(),
            queued: self.slaves.iter().map(|s| s.queued as u64).sum(),
            spec_inflight: 0,
            replicas: self.sets.total_live,
        }
    }

    // ---------------------------------------------------- reporting

    /// Fold the per-tenant samples into the SLO report.  Consumes the
    /// latency vectors; call once, at the end of the run.
    pub(crate) fn traffic_report(&mut self) -> TrafficReport {
        let span = self.makespan.max(1e-9);
        let tspec = self.tspec;
        let mut tenants = Vec::with_capacity(tspec.tenants.len());
        for (i, t) in tspec.tenants.iter().enumerate() {
            let lat = std::mem::take(&mut self.t_lat_ms[i]);
            let (mean, p50, p95, p99) = match Summary::of(&lat) {
                Some(s) => (s.mean, s.p50, s.p95, s.p99),
                None => (0.0, 0.0, 0.0, 0.0),
            };
            tenants.push(TenantSlo {
                name: t.name.clone(),
                requests: self.t_requests[i],
                completed: self.t_completed[i],
                rejected: self.t_rejected[i],
                unavailable: self.t_unavailable[i],
                mean_ms: mean,
                p50_ms: p50,
                p95_ms: p95,
                p99_ms: p99,
                throughput_rps: self.t_completed[i] as f64 / span,
                gbytes: self.t_bytes[i] / 1e9,
            });
        }
        let meta_total = self.meta_hits + self.meta_misses;
        TrafficReport {
            tenants,
            requests: self.issued,
            completed: self.completed,
            rejected: self.rejected,
            unavailable: self.unavailable,
            makespan_secs: self.makespan,
            meta_hit_rate: if meta_total == 0 {
                0.0
            } else {
                self.meta_hits as f64 / meta_total as f64
            },
            conn_hit_rate: self.conn.hit_rate(),
            reassignments: self.reassignments,
            replica_gbytes: self.replica_bytes / 1e9,
            near_fraction: if self.completed == 0 {
                0.0
            } else {
                self.near_served as f64 / self.completed as f64
            },
            peak_queue: self.peak_queue,
            sessions_touched: match &self.sessions {
                Sessions::Dense(v) => v.len() as u64,
                Sessions::Sparse(m) => m.len() as u64,
            },
        }
    }
}

/// Deterministic client -> attachment-node assignment, spread by a
/// per-client hash so populations cover the cloud evenly.
fn client_node(seed: u64, client: u32, nodes: usize) -> u32 {
    let mut sm = SplitMix64::new(seed ^ 0x5ec7_0a5e ^ client as u64);
    (sm.next_u64() % nodes.max(1) as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{run_scenario, FaultSpec};
    use crate::service::TenantSpec;
    use crate::topology::TopologySpec;

    /// 8-node, 2-site traffic scenario small enough for test time.
    fn small_spec(requests: u64, rps: f64) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(2, 2, 2);
        spec.name = "traffic-test".into();
        // Service-only: with a workload present the colocation engine
        // would run instead (it has its own tests).
        spec.workload = None;
        spec.traffic = Some(TrafficSpec {
            clients: 1000,
            requests,
            files: 64,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps },
            shape: ArrivalShape::Flat,
            tenants: vec![
                TenantSpec {
                    name: "web".into(),
                    weight: 0.8,
                    write_fraction: 0.1,
                    object_bytes: 1.0e6,
                    priority: 0,
                },
                TenantSpec {
                    name: "bulk".into(),
                    weight: 0.2,
                    write_fraction: 0.5,
                    object_bytes: 8.0e6,
                    priority: 0,
                },
            ],
        });
        spec
    }

    fn traffic(r: &ScenarioReport) -> &TrafficReport {
        r.traffic.as_ref().expect("traffic report present")
    }

    #[test]
    fn open_loop_completes_and_is_deterministic() {
        let spec = small_spec(2000, 400.0);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same report");
        let t = traffic(&a);
        assert_eq!(t.requests, 2000);
        assert_eq!(t.completed + t.rejected + t.unavailable, 2000);
        assert!(t.completed > 0);
        assert_eq!(t.unavailable, 0, "no faults: nothing unavailable");
        assert!(a.makespan_secs > 0.0);
        for slo in &t.tenants {
            if slo.completed > 0 {
                assert!(slo.p50_ms > 0.0);
                assert!(slo.p99_ms >= slo.p95_ms && slo.p95_ms >= slo.p50_ms);
            }
        }
    }

    #[test]
    fn closed_loop_self_clocks_without_rejections() {
        // 20 clients x ~75 requests each: enough re-visits for the
        // per-session metadata cache to warm past its cold start.
        let mut spec = small_spec(1500, 0.0);
        spec.traffic.as_mut().unwrap().clients = 20;
        spec.traffic.as_mut().unwrap().arrival = ArrivalProcess::Closed { think_secs: 0.02 };
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert_eq!(t.completed, 1500, "closed loop self-clocks: no shedding");
        assert_eq!(t.rejected, 0);
        assert!(
            t.meta_hit_rate > 0.1,
            "small population over a small catalog re-hits its metadata \
             cache (got {})",
            t.meta_hit_rate
        );
        assert!(t.conn_hit_rate > 0.5, "node-pair connections get reused");
    }

    #[test]
    fn overload_sheds_but_serves_every_tenant() {
        // 8 nodes cannot serve 50k rps of multi-MB objects: bounded
        // queues must shed, and round-robin service must keep both
        // tenants progressing.
        let spec = small_spec(3000, 50_000.0);
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert!(t.rejected > 0, "overload must shed");
        for slo in &t.tenants {
            assert!(slo.completed > 0, "tenant {} starved", slo.name);
        }
        assert!(t.peak_queue > 0);
    }

    #[test]
    fn crash_reroutes_to_surviving_replicas() {
        let mut spec = small_spec(2000, 400.0);
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 1.0,
            node: 1,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "faulted runs stay deterministic");
        let t = traffic(&a);
        assert_eq!(a.nodes_crashed, 1);
        assert!(t.reassignments > 0, "in-flight work must re-route");
        assert_eq!(t.completed + t.rejected + t.unavailable, 2000);
        assert!(
            t.completed > 1500,
            "rack-diverse replicas keep most data serveable ({})",
            t.completed
        );
        assert_eq!(t.unavailable, 0, "one crash never exhausts the retry budget");
    }

    #[test]
    fn brownout_raises_latency() {
        let mut spec = small_spec(1500, 300.0);
        let clean = run_scenario(&spec).unwrap();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.02,
        });
        let braked = run_scenario(&spec).unwrap();
        let (c, d) = (traffic(&clean), traffic(&braked));
        assert!(
            d.tenants[0].p99_ms > c.tenants[0].p99_ms,
            "choked uplink must show in p99: {} vs {}",
            d.tenants[0].p99_ms,
            c.tenants[0].p99_ms
        );
    }

    #[test]
    fn writes_replicate_in_background() {
        let mut spec = small_spec(500, 200.0);
        spec.traffic.as_mut().unwrap().tenants = vec![TenantSpec {
            name: "ingest".into(),
            weight: 1.0,
            write_fraction: 1.0,
            object_bytes: 2.0e6,
            priority: 0,
        }];
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert!(t.completed > 0);
        assert!(
            t.replica_gbytes > 0.0,
            "completed writes must copy to the partner replica"
        );
    }

    #[test]
    fn straggler_slows_its_slaves_service() {
        let mut spec = small_spec(1500, 300.0);
        let clean = run_scenario(&spec).unwrap();
        for node in 0..4 {
            spec.faults.push(FaultSpec::Straggler { node, factor: 0.1 });
        }
        let slowed = run_scenario(&spec).unwrap();
        assert!(
            traffic(&slowed).tenants[0].p99_ms > traffic(&clean).tenants[0].p99_ms,
            "slow disks must show in tail latency"
        );
    }

    #[test]
    fn scenario_name_is_preserved() {
        let spec = small_spec(200, 100.0);
        let r = run_scenario(&spec).unwrap();
        assert_eq!(r.name, "traffic-test");
        assert_eq!(r.workload, "traffic");
    }

    // ------------------------------------------------ elastic scaling

    /// Elastic variant of `small_spec`: hard skew, bursty arrivals and
    /// a watermark scaler with room to grow above the 2-copy floor.
    fn elastic_spec(requests: u64, rps: f64) -> ScenarioSpec {
        let mut spec = small_spec(requests, rps);
        let t = spec.traffic.as_mut().unwrap();
        t.files = 32;
        t.zipf_theta = 1.2;
        t.shape = ArrivalShape::Bursty {
            period_secs: 2.0,
            burst_secs: 0.6,
            amplitude: 4.0,
        };
        spec.replication = Some(ReplicationSpec {
            policy: ScalerPolicy::Watermark,
            min_replicas: 2,
            max_replicas: 5,
            interval_secs: 0.25,
            high_reads_per_sec: 2.0,
            low_reads_per_sec: 0.25,
            max_grows_per_tick: 8,
            max_sheds_per_tick: 8,
        });
        spec
    }

    fn elasticity(r: &ScenarioReport) -> &ElasticityReport {
        r.elasticity.as_ref().expect("elasticity report present")
    }

    #[test]
    fn elastic_run_is_deterministic_and_scales() {
        let spec = elastic_spec(3000, 700.0);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "elastic runs stay deterministic");
        let e = elasticity(&a);
        assert_eq!(e.policy, "watermark");
        assert_eq!(e.invariant_violations, 0);
        assert!(e.grows > 0, "a hot skew under bursts must trigger grows");
        assert!(e.rereplication.total() > 0.0, "grows move real bytes");
        assert!(e.peak_replicas >= e.final_replicas);
        assert!(e.sheds >= e.drained_sheds);
        assert!(!e.replica_timeline.is_empty());
        let t = traffic(&a);
        assert_eq!(t.completed + t.rejected + t.unavailable, 3000);
    }

    #[test]
    fn watermark_beats_static_hot_p99() {
        // The acceptance gate: under a skewed, bursty open-loop load
        // the watermark policy's extra replicas of hot files must cut
        // the hot tenant's p99 relative to the same-seed static run.
        let spec = elastic_spec(4000, 1200.0);
        let r = run_scenario(&spec).unwrap();
        let e = elasticity(&r);
        assert!(e.grows > 0);
        let hot = e
            .tenant_deltas
            .iter()
            .find(|d| d.name == "web")
            .expect("hot tenant delta present");
        assert!(
            hot.p99_delta_ms <= 0.0,
            "watermark must not worsen hot-tenant p99 (delta {} ms)",
            hot.p99_delta_ms
        );
    }

    #[test]
    fn scaler_off_equals_static_policy() {
        // No [replication] block and an explicit static policy must be
        // byte-identical in everything but the elasticity summary: the
        // static scaler schedules no ticks and moves no replicas.
        let base = small_spec(2000, 400.0);
        let mut stat = base.clone();
        stat.replication = Some(ReplicationSpec {
            policy: ScalerPolicy::Static,
            min_replicas: 2,
            max_replicas: 4,
            interval_secs: 0.5,
            high_reads_per_sec: 10.0,
            low_reads_per_sec: 0.1,
            max_grows_per_tick: 4,
            max_sheds_per_tick: 4,
        });
        let a = run_scenario(&base).unwrap();
        let b = run_scenario(&stat).unwrap();
        assert!(a.elasticity.is_none());
        let e = elasticity(&b);
        assert_eq!((e.policy, e.grows, e.sheds), ("static", 0, 0));
        assert_eq!(e.invariant_violations, 0);
        assert_eq!(a.traffic, b.traffic, "static scaler must be a no-op");
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_secs, b.makespan_secs);
    }

    #[test]
    fn million_lazy_clients_touch_bounded_sessions() {
        // 3M configured clients, 20k requests: the sparse session store
        // must only materialise state for clients that actually arrive
        // (the dense path would be 3M entries before the first event).
        let mut spec = small_spec(20_000, 4000.0);
        spec.traffic.as_mut().unwrap().clients = 3_000_000;
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert_eq!(t.completed + t.rejected + t.unavailable, 20_000);
        assert!(t.sessions_touched > 0);
        assert!(
            t.sessions_touched <= 20_000,
            "at most one session per request, got {}",
            t.sessions_touched
        );
    }

    #[test]
    fn crash_mid_scaling_keeps_replica_invariants() {
        let mut spec = elastic_spec(3000, 700.0);
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 0.8,
            node: 1,
        });
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 1.6,
            node: 5,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "faulted elastic runs stay deterministic");
        let e = elasticity(&a);
        assert_eq!(
            e.invariant_violations, 0,
            "no replica may survive on a crashed node"
        );
        let t = traffic(&a);
        assert_eq!(t.completed + t.rejected + t.unavailable, 3000);
    }
}

//! The traffic engine — deterministic, event-driven service of client
//! requests against a simulated Sector cloud (DESIGN.md §10).
//!
//! Every request walks the §4 access flow:
//!
//!   1. the client's session checks its metadata cache; on a miss the
//!      lookup routes through the real [`ChordRing`] (hop count × mean
//!      overlay RTT + the response RTT), and the answer is cached with
//!      a TTL;
//!   2. replicas are ranked same-node > same-rack > same-site > WAN
//!      and the request is admitted at the first replica with a free
//!      service slot, queued at the first with queue room, or rejected
//!      when every live replica is saturated (bounded queues: overload
//!      degrades by shedding, not by queueing without limit);
//!   3. a (cached) data connection is acquired — a cache miss pays one
//!      handshake RTT (§4: "frequent data transfers between the same
//!      pair of nodes do not need to set up a data connection every
//!      time");
//!   4. the bytes ride a `sim::netsim` flow whose path includes the
//!      slave's disk (a per-node link, so concurrent slots share the
//!      spindle), the node NICs and any rack/site uplinks — WAN
//!      brown-outs and stragglers therefore squeeze exactly the flows
//!      that cross them.
//!
//! Fair scheduling: each slave drains its bounded queue round-robin
//! across tenants, so a backlogged bulk tenant cannot starve an
//! interactive one.  Faults compose with the stream: a crash cancels
//! the dead slave's flows and re-dispatches its requests to surviving
//! replicas (clients' edge attachment outlives the storage process —
//! the NIC and switch ports are still there), and the Chord ring drops
//! the node so later lookups route to its successor.
//!
//! Determinism contract: same spec, same report, byte for byte — all
//! randomness flows from the spec seed through forked [`Pcg64`]
//! streams, and every container iterated during the run is ordered.
//!
//! Substrate sharing: the engine does NOT own its network, event queue
//! or fault state — every method borrows them from the driving loop.
//! `run_traffic` is the standalone driver (service-only scenarios),
//! a thin [`core::Harness`] over the shared engine core (DESIGN.md
//! §14); `scenario::colocate` drives the same engine interleaved with
//! a batch Sphere job on one shared substrate (DESIGN.md §11).

use std::collections::{BTreeMap, VecDeque};

use crate::config::{SimConfig, TransportKind};
use crate::metrics::Metrics;
use crate::routing::chord::{ChordRing, hash_name};
use crate::scenario::core::{self, CoreEv, FaultEv, Harness};
use crate::scenario::engine::FaultState;
use crate::scenario::trace::{HarnessGauges, TraceRecorder, Tracer};
use crate::scenario::{ScenarioReport, ScenarioSpec};
use crate::sim::event::EventQueue;
use crate::sim::netsim::{FlowId, LinkId, NetSim};
use crate::sphere::simjob::udt_efficiency;
use crate::topology::{NetLinks, Proximity, Testbed, rack_diverse_replica};
use crate::transport::{ConnectionCache, TransportModels};
use crate::util::rng::{Pcg64, SplitMix64};
use crate::util::stats::Summary;

use super::session::{ClientSession, rank_replicas};
use super::{ArrivalProcess, TrafficSpec};

/// Re-dispatch budget per request (crash re-routes).
const MAX_ATTEMPTS: u8 = 4;

/// Per-tenant service-level objective measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSlo {
    pub name: String,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub unavailable: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Completed requests per simulated second.
    pub throughput_rps: f64,
    pub gbytes: f64,
}

/// What a traffic run produced (the SLO report).
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficReport {
    pub tenants: Vec<TenantSlo>,
    pub requests: u64,
    pub completed: u64,
    pub rejected: u64,
    pub unavailable: u64,
    pub makespan_secs: f64,
    /// Client-side metadata cache hit rate (§4 step 2 short-circuit).
    pub meta_hit_rate: f64,
    /// Node-pair data-connection cache hit rate (§4).
    pub conn_hit_rate: f64,
    /// Requests re-dispatched after a slave crash.
    pub reassignments: u64,
    /// Background write-replication volume (not client-visible).
    pub replica_gbytes: f64,
    /// Fraction of completed requests served same-node or same-rack.
    pub near_fraction: f64,
    /// Deepest any slave's admission queue got.
    pub peak_queue: usize,
}

impl TrafficReport {
    /// Record the report into a shared metrics registry (counters for
    /// totals, gauges for the per-tenant percentiles in ms).
    pub fn record_into(&self, m: &Metrics) {
        m.add("service.requests", self.requests);
        m.add("service.completed", self.completed);
        m.add("service.rejected", self.rejected);
        m.add("service.unavailable", self.unavailable);
        m.add("service.reassignments", self.reassignments);
        m.gauge_set("service.peak_queue", self.peak_queue as i64);
        m.gauge_set(
            "service.meta_hit_pct",
            (self.meta_hit_rate * 100.0).round() as i64,
        );
        m.gauge_set(
            "service.conn_hit_pct",
            (self.conn_hit_rate * 100.0).round() as i64,
        );
        for t in &self.tenants {
            m.add(&format!("service.{}.completed", t.name), t.completed);
            m.add(&format!("service.{}.rejected", t.name), t.rejected);
            m.gauge_set(
                &format!("service.{}.p99_ms", t.name),
                t.p99_ms.round() as i64,
            );
        }
    }
}

/// Run a service-only traffic scenario to completion.  Deterministic:
/// no wall clock, no ambient randomness — the spec is the only input.
/// This is the standalone driver; colocated scenarios drive the same
/// [`Engine`] from `scenario::colocate` instead.
pub fn run_traffic(
    spec: &ScenarioSpec,
    testbed: &Testbed,
    rec: &TraceRecorder,
) -> Result<ScenarioReport, String> {
    let tspec = spec
        .traffic
        .as_ref()
        .ok_or("run_traffic called without a [traffic] block")?;
    tspec.validate()?;
    let n = testbed.nodes();
    let mut state = FaultState::new(&spec.faults, n);
    let mut net =
        NetSim::with_capacity(4 * n + 2 * testbed.racks() + 2 * testbed.site_names.len());
    let links = testbed.build_network(&mut net);
    let mut q: EventQueue<Ev> = EventQueue::with_capacity(4096);
    let tracer = rec.tracer("traffic");
    let mut engine = Engine::new(spec, tspec, testbed, &mut net, links.clone(), &state, tracer)?;
    core::schedule_faults(&mut state, &mut q, 0.0);
    engine.schedule_arrivals(&mut q);

    let out = {
        let mut h = TrafficHarness {
            engine: &mut engine,
        };
        let tracer = rec.tracer("traffic");
        core::drive(&mut h, &mut net, &mut q, &mut state, &links, testbed, &tracer)?
    };
    engine.events = out.events;

    let traffic = engine.traffic_report();
    Ok(ScenarioReport {
        name: spec.name.clone(),
        workload: "traffic",
        nodes: testbed.nodes(),
        racks: testbed.racks(),
        sites: testbed.site_names.len(),
        makespan_secs: traffic.makespan_secs,
        events: engine.events,
        segments: engine.completed as usize,
        reassignments: engine.reassignments,
        locality_fraction: traffic.near_fraction,
        shuffle_gbytes: engine.served_bytes / 1e9,
        faults_injected: state.injected,
        nodes_crashed: state.crashes,
        speculative_launched: 0,
        speculative_won: 0,
        traffic: Some(traffic),
        colocation: None,
        comparison: None,
        angle: None,
        trace_digest: String::new(),
    })
}

// ------------------------------------------------------------ events

/// Service-side events.  The fault plan rides the shared
/// [`FaultEv`] vocabulary, scheduled by `core::schedule_faults` and
/// intercepted by `core::drive`; the engine itself only ever emits the
/// first three variants.
pub(crate) enum Ev {
    /// Open-loop arrival tick: issue one request, schedule the next.
    Arrive,
    /// Closed-loop client finished thinking.
    ClientWake { client: u32 },
    /// Metadata resolved: admit the request at a replica.
    Dispatch { req: u32 },
    /// Crash / brown-out events owned by `scenario::core`.
    Fault(FaultEv),
}

impl CoreEv for Ev {
    fn from_fault(f: FaultEv) -> Ev {
        Ev::Fault(f)
    }

    fn to_fault(&self) -> Option<FaultEv> {
        match self {
            Ev::Fault(f) => Some(*f),
            _ => None,
        }
    }

    fn trace_name(&self) -> &'static str {
        match self {
            Ev::Arrive => "arrive",
            Ev::ClientWake { .. } => "client_wake",
            Ev::Dispatch { .. } => "dispatch",
            Ev::Fault(_) => "fault",
        }
    }
}

/// The standalone traffic driver plugged into the core loop: the
/// engine is the whole workload, with no post-wave hook.
struct TrafficHarness<'e, 'a> {
    engine: &'e mut Engine<'a>,
}

impl<'e, 'a> Harness for TrafficHarness<'e, 'a> {
    type Ev = Ev;

    fn finished(&self, net: &NetSim) -> bool {
        self.engine.done() && net.active_flows() == 0
    }

    fn flow_done(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.flow_done(fid, now, net, q, state);
        Ok(())
    }

    fn handle(
        &mut self,
        ev: Ev,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.handle_event(ev, now, net, q, state);
        Ok(())
    }

    fn on_crash(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        self.engine.on_crash(node, now, net, q);
        Ok(())
    }

    fn after_wave(
        &mut self,
        _now: f64,
        _drained: bool,
        _net: &mut NetSim,
        _q: &mut EventQueue<Ev>,
        _state: &mut FaultState,
    ) -> Result<(), String> {
        Ok(())
    }

    fn gauges(&self) -> HarnessGauges {
        self.engine.gauges()
    }
}

enum FlowKind {
    /// A client-visible request transfer.
    Service { req: u32 },
    /// Background write replication between the recorded endpoints.
    Replicate { src: u32, dst: u32 },
}

// ------------------------------------------------------------ catalog

/// The object catalog: placement and popularity, fixed at build time.
struct Catalog {
    /// FNV hash of each object's name (the Chord lookup key).
    hash: Vec<u64>,
    primary: Vec<u32>,
    replica: Vec<u32>,
    /// Normalized popularity CDF over key ids (Zipf ranks scattered
    /// over the id space by a seeded shuffle, so hot keys spread
    /// across slaves instead of clustering at id 0).
    cdf: Vec<f64>,
}

impl Catalog {
    fn build(
        files: usize,
        theta: f64,
        nodes: usize,
        testbed: &Testbed,
        rng: &mut Pcg64,
    ) -> Catalog {
        // The replica partner depends only on the primary node:
        // precompute it per node instead of re-deriving it per file.
        let partner: Vec<u32> = (0..nodes)
            .map(|n| rack_diverse_replica(testbed, n) as u32)
            .collect();
        let mut hash = Vec::with_capacity(files);
        let mut primary = Vec::with_capacity(files);
        let mut replica = Vec::with_capacity(files);
        for k in 0..files {
            hash.push(hash_name(&format!("svc/obj{k:08}.dat")));
            let p = rng.gen_range(nodes as u64) as u32;
            primary.push(p);
            replica.push(partner[p as usize]);
        }
        let mut perm: Vec<u32> = (0..files as u32).collect();
        rng.shuffle(&mut perm);
        let mut weight = vec![0.0f64; files];
        for (rank, &key) in perm.iter().enumerate() {
            weight[key as usize] = 1.0 / ((rank + 1) as f64).powf(theta);
        }
        let total: f64 = weight.iter().sum();
        let mut acc = 0.0;
        let mut cdf = Vec::with_capacity(files);
        for w in &weight {
            acc += w / total;
            cdf.push(acc);
        }
        if let Some(last) = cdf.last_mut() {
            *last = 1.0;
        }
        Catalog {
            hash,
            primary,
            replica,
            cdf,
        }
    }

    fn sample_key(&self, rng: &mut Pcg64) -> u32 {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c <= u) as u32
    }
}

// ------------------------------------------------------------ sessions

/// Client-session store: dense for closed-loop populations (every
/// client participates), lazy for open-loop ones (only clients the
/// arrival process actually picks get a session).
enum Sessions {
    Dense(Vec<ClientSession>),
    Sparse(BTreeMap<u32, ClientSession>),
}

impl Sessions {
    fn get_or_create(&mut self, id: u32, node: u32) -> &mut ClientSession {
        match self {
            Sessions::Dense(v) => &mut v[id as usize],
            Sessions::Sparse(m) => m
                .entry(id)
                .or_insert_with(|| ClientSession::new(id, node)),
        }
    }
}

// ------------------------------------------------------------ requests

struct Request {
    client: u32,
    tenant: u16,
    key: u32,
    write: bool,
    arrived: f64,
    /// Latency components not simulated as events (connection setup).
    overhead: f64,
    /// Slave currently serving or queueing this request.
    slave: u32,
    attempts: u8,
    /// Served same-node or same-rack (set at service start).
    near: bool,
    /// Lookup missed: fill the session's metadata cache when the
    /// resolution completes (at dispatch), not at issue — a concurrent
    /// request for the same key must not hit metadata still in flight.
    fill_meta: bool,
}

struct SlaveState {
    active: usize,
    /// Per-tenant admission queues, drained round-robin.
    queues: Vec<VecDeque<u32>>,
    queued: usize,
    /// Round-robin pointer over tenants.
    rr: usize,
}

// ------------------------------------------------------------ engine

/// The traffic engine's state.  Borrows its substrate (network, event
/// queue, fault state) per call so a driving loop can share that
/// substrate with other workloads; fields the colocation driver reads
/// for its joint report are `pub(crate)`.
pub(crate) struct Engine<'a> {
    tspec: &'a TrafficSpec,
    testbed: &'a Testbed,
    cfg: &'a SimConfig,
    models: TransportModels,
    links: NetLinks,
    /// One link per node modelling its read/write spindle: concurrent
    /// service slots share the disk via max-min fairness, and a
    /// straggler is simply a slower disk link.  Shared with the batch
    /// job's segment I/O in colocated runs.
    pub(crate) disk_read: Vec<LinkId>,
    pub(crate) disk_write: Vec<LinkId>,
    /// Nominal link capacities (rate caps are computed against these so
    /// a degradation window squeezes flows through the shared link and
    /// lifts when it ends).
    pub(crate) nominal_caps: Vec<f64>,
    /// Observability feed: admission verdicts and cancelled transfers
    /// go straight to the run's trace recorder (cheap no-ops when
    /// capture is off — the digest still folds them in).
    tracer: Tracer,
    ring: ChordRing,
    ring_ids: Vec<u64>,
    ring_to_node: BTreeMap<u64, u32>,
    catalog: Catalog,
    sessions: Sessions,
    conn: ConnectionCache,
    rng: Pcg64,
    seed: u64,
    mean_rtt: f64,
    requests: Vec<Request>,
    slaves: Vec<SlaveState>,
    flows: BTreeMap<FlowId, FlowKind>,
    // ---- counters
    issued: u64,
    outstanding: u64,
    pub(crate) completed: u64,
    rejected: u64,
    unavailable: u64,
    pub(crate) events: u64,
    pub(crate) reassignments: u64,
    near_served: u64,
    meta_hits: u64,
    meta_misses: u64,
    pub(crate) served_bytes: f64,
    replica_bytes: f64,
    peak_queue: usize,
    makespan: f64,
    // ---- per tenant
    t_requests: Vec<u64>,
    t_completed: Vec<u64>,
    t_rejected: Vec<u64>,
    t_unavailable: Vec<u64>,
    t_bytes: Vec<f64>,
    t_lat_ms: Vec<Vec<f64>>,
    tenant_cdf: Vec<f64>,
}

impl<'a> Engine<'a> {
    /// Build the engine against an externally-owned network that
    /// already carries the topology links (`links`).  Adds the
    /// per-node disk links to `net`; `state` supplies the static
    /// straggler factors baked into those disk capacities.
    pub(crate) fn new(
        spec: &'a ScenarioSpec,
        tspec: &'a TrafficSpec,
        testbed: &'a Testbed,
        net: &mut NetSim,
        links: NetLinks,
        state: &FaultState,
        tracer: Tracer,
    ) -> Result<Engine<'a>, String> {
        let cfg = &spec.cfg;
        let n = testbed.nodes();
        let mut rng = Pcg64::new(cfg.seed);
        let mut ring_rng = rng.fork(1);
        let mut catalog_rng = rng.fork(2);
        let traffic_rng = rng.fork(3);

        let ring_ids: Vec<u64> = (0..n).map(|_| ring_rng.next_u64()).collect();
        let ring = ChordRing::build(&ring_ids);
        let ring_to_node: BTreeMap<u64, u32> = ring_ids
            .iter()
            .enumerate()
            .map(|(i, &id)| (id, i as u32))
            .collect();
        let catalog = Catalog::build(tspec.files, tspec.zipf_theta, n, testbed, &mut catalog_rng);

        // Disk links: one read and one write spindle link per node
        // (straggler factors are static, so they bake into the disk
        // capacity).
        let read_eff = cfg.hardware.disk_read_bps * cfg.sphere.io_efficiency;
        let write_eff = cfg.hardware.disk_write_bps * cfg.sphere.io_efficiency;
        let disk_read: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((read_eff * state.factor[i]).max(1.0)))
            .collect();
        let disk_write: Vec<LinkId> = (0..n)
            .map(|i| net.add_link((write_eff * state.factor[i]).max(1.0)))
            .collect();
        let nominal_caps: Vec<f64> = (0..net.link_count())
            .map(|i| net.link_capacity(LinkId(i)))
            .collect();

        let mut acc = 0.0;
        for a in 0..n {
            for b in 0..n {
                acc += testbed.rtt_secs(a, b);
            }
        }
        let mean_rtt = acc / (n * n).max(1) as f64;

        let tenants = tspec.tenants.len();
        let total_weight: f64 = tspec.tenants.iter().map(|t| t.weight).sum();
        let mut tenant_cdf = Vec::with_capacity(tenants);
        let mut tacc = 0.0;
        for t in &tspec.tenants {
            tacc += t.weight / total_weight;
            tenant_cdf.push(tacc);
        }
        if let Some(last) = tenant_cdf.last_mut() {
            *last = 1.0;
        }

        let sessions = match tspec.arrival {
            ArrivalProcess::Closed { .. } => {
                let mut v = Vec::with_capacity(tspec.clients);
                for id in 0..tspec.clients as u32 {
                    v.push(ClientSession::new(id, client_node(cfg.seed, id, n)));
                }
                Sessions::Dense(v)
            }
            ArrivalProcess::Open { .. } => Sessions::Sparse(BTreeMap::new()),
        };

        let slaves = (0..n)
            .map(|_| SlaveState {
                active: 0,
                queues: (0..tenants).map(|_| VecDeque::new()).collect(),
                queued: 0,
                rr: 0,
            })
            .collect();

        Ok(Engine {
            tspec,
            testbed,
            cfg,
            models: TransportModels::default(),
            links,
            disk_read,
            disk_write,
            nominal_caps,
            tracer,
            ring,
            ring_ids,
            ring_to_node,
            catalog,
            sessions,
            conn: ConnectionCache::new(
                cfg.service.conn_cache_entries,
                cfg.service.conn_idle_secs,
            ),
            rng: traffic_rng,
            seed: cfg.seed,
            mean_rtt,
            requests: Vec::with_capacity(tspec.requests.min(1 << 22) as usize),
            slaves,
            flows: BTreeMap::new(),
            issued: 0,
            outstanding: 0,
            completed: 0,
            rejected: 0,
            unavailable: 0,
            events: 0,
            reassignments: 0,
            near_served: 0,
            meta_hits: 0,
            meta_misses: 0,
            served_bytes: 0.0,
            replica_bytes: 0.0,
            peak_queue: 0,
            makespan: 0.0,
            t_requests: vec![0; tenants],
            t_completed: vec![0; tenants],
            t_rejected: vec![0; tenants],
            t_unavailable: vec![0; tenants],
            t_bytes: vec![0.0; tenants],
            t_lat_ms: (0..tenants).map(|_| Vec::new()).collect(),
            tenant_cdf,
        })
    }

    // ---------------------------------------------------- scheduling

    pub(crate) fn schedule_arrivals<E: From<Ev>>(&mut self, q: &mut EventQueue<E>) {
        match self.tspec.arrival {
            ArrivalProcess::Open { rps } => {
                let dt = self.rng.next_exp(rps);
                q.push_at(dt, Ev::Arrive.into());
            }
            ArrivalProcess::Closed { think_secs } => {
                for client in 0..self.tspec.clients as u32 {
                    let dt = if think_secs > 0.0 {
                        self.rng.next_exp(1.0 / think_secs)
                    } else {
                        0.0
                    };
                    q.push_at(dt, Ev::ClientWake { client }.into());
                }
            }
        }
    }

    /// All requests issued and none outstanding (flows are the driving
    /// loop's to check — it owns the network).
    pub(crate) fn done(&self) -> bool {
        self.issued >= self.tspec.requests && self.outstanding == 0
    }

    // ---------------------------------------------------- request intake

    /// Weighted tenant pick from the engine's main stream (open loop:
    /// the mix is a property of the aggregate arrival process).
    fn sample_tenant(&mut self) -> u16 {
        let u = self.rng.next_f64();
        self.tenant_cdf.partition_point(|&c| c <= u) as u16
    }

    /// Closed loop: a client belongs to one tenant for its whole life,
    /// picked from a per-client hash stream so the assignment does not
    /// depend on arrival interleaving.
    fn tenant_of_client(&self, client: u32) -> u16 {
        let mut sm = SplitMix64::new(self.seed.rotate_left(17) ^ client as u64);
        let u = (sm.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        self.tenant_cdf.partition_point(|&c| c <= u) as u16
    }

    fn issue_request<E: From<Ev>>(
        &mut self,
        client: u32,
        tenant: u16,
        now: f64,
        state: &FaultState,
        q: &mut EventQueue<E>,
    ) {
        let key = self.catalog.sample_key(&mut self.rng);
        let write = self.rng.next_f64() < self.tspec.tenants[tenant as usize].write_fraction;
        let lookup_secs = self.resolve_meta(client, key, now, state);
        let req = self.requests.len() as u32;
        self.requests.push(Request {
            client,
            tenant,
            key,
            write,
            arrived: now,
            overhead: 0.0,
            slave: u32::MAX,
            attempts: 0,
            near: false,
            fill_meta: lookup_secs > 0.0,
        });
        self.issued += 1;
        self.outstanding += 1;
        self.t_requests[tenant as usize] += 1;
        q.push_at(now + lookup_secs, Ev::Dispatch { req }.into());
    }

    /// §4 step 2: resolve the object's locations — from the session's
    /// metadata cache when fresh, else through the Chord ring.  Returns
    /// the lookup latency.
    fn resolve_meta(&mut self, client: u32, key: u32, now: f64, state: &FaultState) -> f64 {
        let n = self.testbed.nodes();
        let node = client_node(self.seed, client, n);
        let (home, hit) = {
            let s = self.sessions.get_or_create(client, node);
            (s.node as usize, s.meta_lookup(key as u64, now))
        };
        if hit {
            self.meta_hits += 1;
            return 0.0;
        }
        self.meta_misses += 1;
        // A crashed home node's clients re-enter the overlay through
        // the first live node.
        let start = if state.dead[home] {
            *state.alive().first().unwrap_or(&home)
        } else {
            home
        };
        let (owner_id, hops) = self
            .ring
            .lookup(self.ring_ids[start], self.catalog.hash[key as usize])
            .expect("non-empty ring");
        let owner = self.ring_to_node[&owner_id] as usize;
        // The cache entry is written when the resolution lands
        // (dispatch time), via Request::fill_meta — not here.
        hops as f64 * self.mean_rtt + self.testbed.rtt_secs(home, owner)
    }

    // ---------------------------------------------------- admission

    /// Live candidate slaves for a request, in the client's preference
    /// order.  Writes must land on the primary (or the surviving
    /// replica when the primary is down); reads take any live copy.
    fn candidates(&self, req: u32, state: &FaultState) -> Vec<u32> {
        let r = &self.requests[req as usize];
        let primary = self.catalog.primary[r.key as usize];
        let replica = self.catalog.replica[r.key as usize];
        if r.write {
            for cand in [primary, replica] {
                if !state.dead[cand as usize] {
                    return vec![cand];
                }
            }
            return Vec::new();
        }
        let mut cands: Vec<u32> = [primary, replica]
            .into_iter()
            .filter(|&c| !state.dead[c as usize])
            .collect();
        cands.dedup();
        let home = client_node(self.seed, r.client, self.testbed.nodes()) as usize;
        rank_replicas(self.testbed, home, &mut cands);
        cands
    }

    fn dispatch<E: From<Ev>>(
        &mut self,
        req: u32,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) {
        // A missed lookup has now resolved: fill the session's
        // metadata cache, TTL clocked from the resolution.
        if self.requests[req as usize].fill_meta {
            self.requests[req as usize].fill_meta = false;
            let (client, key) = {
                let r = &self.requests[req as usize];
                (r.client, r.key)
            };
            let node = client_node(self.seed, client, self.testbed.nodes());
            let ttl = self.cfg.service.meta_ttl_secs;
            let cap = self.cfg.service.meta_cache_entries;
            self.sessions
                .get_or_create(client, node)
                .meta_insert(key as u64, now + ttl, cap);
        }
        let cands = self.candidates(req, state);
        if cands.is_empty() || self.requests[req as usize].attempts >= MAX_ATTEMPTS {
            self.trace_admission(req, now, "unavailable", -1);
            self.finish_non_served(req, now, false, q);
            return;
        }
        self.requests[req as usize].attempts += 1;
        let slots = self.cfg.service.slots_per_slave.max(1);
        // Pass 1: an idle slot anywhere beats queueing at the nearest.
        for &cand in &cands {
            if self.slaves[cand as usize].active < slots {
                self.trace_admission(req, now, "served", cand as i64);
                self.start_service(req, cand, now, net);
                return;
            }
        }
        // Pass 2: queue room, in preference order.
        let tenant = self.requests[req as usize].tenant as usize;
        for &cand in &cands {
            if self.slaves[cand as usize].queued < self.cfg.service.queue_capacity {
                self.trace_admission(req, now, "queued", cand as i64);
                let ss = &mut self.slaves[cand as usize];
                ss.queues[tenant].push_back(req);
                ss.queued += 1;
                self.peak_queue = self.peak_queue.max(ss.queued);
                self.requests[req as usize].slave = cand;
                return;
            }
        }
        // Every live replica saturated: shed the request.
        self.trace_admission(req, now, "rejected", cands[0] as i64);
        self.finish_non_served(req, now, true, q);
    }

    /// Emit the admission verdict for `req` into the trace, tagged with
    /// the tenant and the slave that took (or shed) it.
    fn trace_admission(&self, req: u32, now: f64, verdict: &'static str, node: i64) {
        let tenant = self.requests[req as usize].tenant as usize;
        self.tracer
            .admission(now, verdict, node, &self.tspec.tenants[tenant].name);
    }

    /// Terminal non-success: `rejected` (admission shed) or
    /// `unavailable` (no live replica / retries exhausted).
    fn finish_non_served<E: From<Ev>>(
        &mut self,
        req: u32,
        now: f64,
        is_rejection: bool,
        q: &mut EventQueue<E>,
    ) {
        let tenant = self.requests[req as usize].tenant as usize;
        if is_rejection {
            self.rejected += 1;
            self.t_rejected[tenant] += 1;
        } else {
            self.unavailable += 1;
            self.t_unavailable[tenant] += 1;
        }
        self.outstanding -= 1;
        self.makespan = self.makespan.max(now);
        let client = self.requests[req as usize].client;
        self.client_think(client, now, q);
    }

    /// Closed loop only: schedule the client's next cycle.
    fn client_think<E: From<Ev>>(&mut self, client: u32, now: f64, q: &mut EventQueue<E>) {
        if let ArrivalProcess::Closed { think_secs } = self.tspec.arrival {
            let dt = if think_secs > 0.0 {
                self.rng.next_exp(1.0 / think_secs)
            } else {
                0.0
            };
            q.push_at(now + dt, Ev::ClientWake { client }.into());
        }
    }

    /// Start a byte transfer from `from` to `to`: the network route
    /// between them, plus the reading/writing disk links of whichever
    /// ends touch a spindle.  The rate cap comes from the transport
    /// protocol against NOMINAL link rates (degradation constrains the
    /// shared links instead, so it lifts when the window ends).
    #[allow(clippy::too_many_arguments)]
    fn start_transfer(
        &mut self,
        from: usize,
        to: usize,
        bytes: f64,
        read_disk: Option<usize>,
        write_disk: Option<usize>,
        kind: FlowKind,
        net: &mut NetSim,
    ) {
        let net_path = self.testbed.path(&self.links, from, to);
        let bottleneck = net_path
            .iter()
            .map(|l| self.nominal_caps[l.0])
            .fold(f64::INFINITY, f64::min)
            .min(self.testbed.nic_bps);
        let rtt = self.testbed.rtt_secs(from, to);
        let proto_cap = match self.cfg.sphere_transport {
            TransportKind::Udt => udt_efficiency(self.models.udt.efficiency, rtt) * bottleneck,
            TransportKind::Tcp => self.models.tcp.rate_cap(bottleneck, rtt),
        };
        let mut path = Vec::with_capacity(net_path.len() + 2);
        if let Some(node) = read_disk {
            path.push(self.disk_read[node]);
        }
        path.extend_from_slice(&net_path);
        if let Some(node) = write_disk {
            path.push(self.disk_write[node]);
        }
        let fid = net.start_flow(&path, bytes.max(1.0), proto_cap.max(1.0));
        self.flows.insert(fid, kind);
    }

    fn start_service(&mut self, req: u32, slave: u32, now: f64, net: &mut NetSim) {
        let n = self.testbed.nodes();
        let (write, tenant, client) = {
            let r = &self.requests[req as usize];
            (r.write, r.tenant as usize, r.client)
        };
        let home = client_node(self.seed, client, n) as usize;
        let s = slave as usize;
        self.slaves[s].active += 1;

        // §4 connection cache: one handshake RTT on a miss, free reuse
        // on a hit.  Keyed by the (server, client-edge) node pair.
        let rtt = self.testbed.rtt_secs(s, home);
        let (a, b) = if write {
            (home as u32, slave)
        } else {
            (slave, home as u32)
        };
        let cached = self.conn.acquire(now, a, b);
        let setup = if cached { 0.0 } else { rtt };

        let bytes = self.tspec.tenants[tenant].object_bytes;
        if write {
            self.start_transfer(home, s, bytes, None, Some(s), FlowKind::Service { req }, net);
        } else {
            self.start_transfer(s, home, bytes, Some(s), None, FlowKind::Service { req }, net);
        }

        let r = &mut self.requests[req as usize];
        r.slave = slave;
        r.overhead += setup;
        r.near = self.testbed.proximity(s, home) <= Proximity::SameRack;
    }

    /// A slot freed at `slave`: serve the next queued request, fair
    /// round-robin across tenants.
    fn dequeue_next(&mut self, slave: u32, now: f64, net: &mut NetSim) {
        let slots = self.cfg.service.slots_per_slave.max(1);
        let s = slave as usize;
        if self.slaves[s].active >= slots || self.slaves[s].queued == 0 {
            return;
        }
        let tenants = self.slaves[s].queues.len();
        for i in 1..=tenants {
            let idx = (self.slaves[s].rr + i) % tenants;
            if let Some(req) = self.slaves[s].queues[idx].pop_front() {
                self.slaves[s].rr = idx;
                self.slaves[s].queued -= 1;
                self.start_service(req, slave, now, net);
                return;
            }
        }
    }

    // ---------------------------------------------------- completion

    /// A network flow landed.  Returns `true` when the flow belonged to
    /// this engine (so a colocated driver can offer each completion to
    /// both sides and count it once).
    pub(crate) fn flow_done<E: From<Ev>>(
        &mut self,
        fid: FlowId,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) -> bool {
        let Some(kind) = self.flows.remove(&fid) else {
            return false;
        };
        let FlowKind::Service { req } = kind else {
            return true; // background replication landed; bytes already counted
        };
        let (slave, tenant, write, key, near, latency_ms, client) = {
            let r = &self.requests[req as usize];
            (
                r.slave,
                r.tenant as usize,
                r.write,
                r.key,
                r.near,
                (now - r.arrived + r.overhead) * 1e3,
                r.client,
            )
        };
        self.slaves[slave as usize].active -= 1;
        self.completed += 1;
        self.outstanding -= 1;
        self.t_completed[tenant] += 1;
        let bytes = self.tspec.tenants[tenant].object_bytes;
        self.t_bytes[tenant] += bytes;
        self.served_bytes += bytes;
        self.t_lat_ms[tenant].push(latency_ms);
        self.near_served += near as u64;
        self.makespan = self.makespan.max(now);

        // A completed write replicates to the rack-diverse partner in
        // the background (paper §4: replicas restored to target count).
        if write {
            let primary = self.catalog.primary[key as usize] as usize;
            let partner = self.catalog.replica[key as usize] as usize;
            let (src, dst) = if slave as usize == primary {
                (primary, partner)
            } else {
                (partner, primary)
            };
            if !state.dead[dst] && src != dst {
                self.start_transfer(
                    src,
                    dst,
                    bytes,
                    Some(src),
                    Some(dst),
                    FlowKind::Replicate {
                        src: src as u32,
                        dst: dst as u32,
                    },
                    net,
                );
                self.replica_bytes += bytes;
            }
        }

        self.dequeue_next(slave, now, net);
        self.client_think(client, now, q);
        true
    }

    // ---------------------------------------------------- faults

    /// React to a crash the driving loop already applied to the shared
    /// `FaultState`: drop the node from the overlay, cancel its
    /// transfers and re-dispatch its requests.
    pub(crate) fn on_crash<E: From<Ev>>(
        &mut self,
        node: usize,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
    ) {
        // The overlay drops the node: later lookups route to its
        // successor (metadata is replicated there in deployed Sector).
        self.ring.leave(self.ring_ids[node]);

        // Cancel transfers served by the dead slave and re-dispatch
        // their requests; background replications touching it are
        // simply dropped (the copy is lost with the node).
        let doomed: Vec<(FlowId, Option<u32>)> = self
            .flows
            .iter()
            .filter_map(|(&fid, kind)| match kind {
                FlowKind::Service { req }
                    if self.requests[*req as usize].slave as usize == node =>
                {
                    Some((fid, Some(*req)))
                }
                FlowKind::Replicate { src, dst }
                    if *src as usize == node || *dst as usize == node =>
                {
                    Some((fid, None))
                }
                _ => None,
            })
            .collect();
        for (fid, req) in doomed {
            self.flows.remove(&fid);
            net.cancel_flow(fid);
            self.tracer.flow_cancel(fid, now);
            if let Some(req) = req {
                self.reassignments += 1;
                q.push_at(now, Ev::Dispatch { req }.into());
            }
        }
        // Re-dispatch everything queued at the dead slave.
        let tenants = self.slaves[node].queues.len();
        for tq in 0..tenants {
            while let Some(req) = self.slaves[node].queues[tq].pop_front() {
                self.reassignments += 1;
                q.push_at(now, Ev::Dispatch { req }.into());
            }
        }
        self.slaves[node].queued = 0;
        self.slaves[node].active = 0;
    }

    // ---------------------------------------------------- event entry

    /// Handle one service-side event.  Fault events are the driving
    /// loop's responsibility (it owns the `FaultState` and the shared
    /// links) and are ignored here.
    pub(crate) fn handle_event<E: From<Ev>>(
        &mut self,
        ev: Ev,
        now: f64,
        net: &mut NetSim,
        q: &mut EventQueue<E>,
        state: &FaultState,
    ) {
        let total = self.tspec.requests;
        match ev {
            Ev::Arrive => {
                if self.issued < total {
                    let tenant = self.sample_tenant();
                    let client = self.rng.gen_range(self.tspec.clients as u64) as u32;
                    self.issue_request(client, tenant, now, state, q);
                    if let ArrivalProcess::Open { rps } = self.tspec.arrival {
                        let dt = self.rng.next_exp(rps);
                        q.push_at(now + dt, Ev::Arrive.into());
                    }
                }
            }
            Ev::ClientWake { client } => {
                if self.issued < total {
                    let tenant = self.tenant_of_client(client);
                    self.issue_request(client, tenant, now, state, q);
                }
            }
            Ev::Dispatch { req } => self.dispatch(req, now, net, q, state),
            Ev::Fault(_) => {}
        }
    }

    /// Scheduler-occupancy gauges for the trace sampler.
    pub(crate) fn gauges(&self) -> HarnessGauges {
        HarnessGauges {
            occupancy: self.slaves.iter().map(|s| s.active as u64).sum(),
            queued: self.slaves.iter().map(|s| s.queued as u64).sum(),
            spec_inflight: 0,
        }
    }

    // ---------------------------------------------------- reporting

    /// Fold the per-tenant samples into the SLO report.  Consumes the
    /// latency vectors; call once, at the end of the run.
    pub(crate) fn traffic_report(&mut self) -> TrafficReport {
        let span = self.makespan.max(1e-9);
        let tspec = self.tspec;
        let mut tenants = Vec::with_capacity(tspec.tenants.len());
        for (i, t) in tspec.tenants.iter().enumerate() {
            let lat = std::mem::take(&mut self.t_lat_ms[i]);
            let (mean, p50, p95, p99) = match Summary::of(&lat) {
                Some(s) => (s.mean, s.p50, s.p95, s.p99),
                None => (0.0, 0.0, 0.0, 0.0),
            };
            tenants.push(TenantSlo {
                name: t.name.clone(),
                requests: self.t_requests[i],
                completed: self.t_completed[i],
                rejected: self.t_rejected[i],
                unavailable: self.t_unavailable[i],
                mean_ms: mean,
                p50_ms: p50,
                p95_ms: p95,
                p99_ms: p99,
                throughput_rps: self.t_completed[i] as f64 / span,
                gbytes: self.t_bytes[i] / 1e9,
            });
        }
        let meta_total = self.meta_hits + self.meta_misses;
        TrafficReport {
            tenants,
            requests: self.issued,
            completed: self.completed,
            rejected: self.rejected,
            unavailable: self.unavailable,
            makespan_secs: self.makespan,
            meta_hit_rate: if meta_total == 0 {
                0.0
            } else {
                self.meta_hits as f64 / meta_total as f64
            },
            conn_hit_rate: self.conn.hit_rate(),
            reassignments: self.reassignments,
            replica_gbytes: self.replica_bytes / 1e9,
            near_fraction: if self.completed == 0 {
                0.0
            } else {
                self.near_served as f64 / self.completed as f64
            },
            peak_queue: self.peak_queue,
        }
    }
}

/// Deterministic client -> attachment-node assignment, spread by a
/// per-client hash so populations cover the cloud evenly.
fn client_node(seed: u64, client: u32, nodes: usize) -> u32 {
    let mut sm = SplitMix64::new(seed ^ 0x5ec7_0a5e ^ client as u64);
    (sm.next_u64() % nodes.max(1) as u64) as u32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::{run_scenario, FaultSpec};
    use crate::service::TenantSpec;
    use crate::topology::TopologySpec;

    /// 8-node, 2-site traffic scenario small enough for test time.
    fn small_spec(requests: u64, rps: f64) -> ScenarioSpec {
        let mut spec = ScenarioSpec::paper_lan8();
        spec.topology = TopologySpec::scale_out(2, 2, 2);
        spec.name = "traffic-test".into();
        // Service-only: with a workload present the colocation engine
        // would run instead (it has its own tests).
        spec.workload = None;
        spec.traffic = Some(TrafficSpec {
            clients: 1000,
            requests,
            files: 64,
            zipf_theta: 0.9,
            arrival: ArrivalProcess::Open { rps },
            tenants: vec![
                TenantSpec {
                    name: "web".into(),
                    weight: 0.8,
                    write_fraction: 0.1,
                    object_bytes: 1.0e6,
                },
                TenantSpec {
                    name: "bulk".into(),
                    weight: 0.2,
                    write_fraction: 0.5,
                    object_bytes: 8.0e6,
                },
            ],
        });
        spec
    }

    fn traffic(r: &ScenarioReport) -> &TrafficReport {
        r.traffic.as_ref().expect("traffic report present")
    }

    #[test]
    fn open_loop_completes_and_is_deterministic() {
        let spec = small_spec(2000, 400.0);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "same spec, same report");
        let t = traffic(&a);
        assert_eq!(t.requests, 2000);
        assert_eq!(t.completed + t.rejected + t.unavailable, 2000);
        assert!(t.completed > 0);
        assert_eq!(t.unavailable, 0, "no faults: nothing unavailable");
        assert!(a.makespan_secs > 0.0);
        for slo in &t.tenants {
            if slo.completed > 0 {
                assert!(slo.p50_ms > 0.0);
                assert!(slo.p99_ms >= slo.p95_ms && slo.p95_ms >= slo.p50_ms);
            }
        }
    }

    #[test]
    fn closed_loop_self_clocks_without_rejections() {
        // 20 clients x ~75 requests each: enough re-visits for the
        // per-session metadata cache to warm past its cold start.
        let mut spec = small_spec(1500, 0.0);
        spec.traffic.as_mut().unwrap().clients = 20;
        spec.traffic.as_mut().unwrap().arrival = ArrivalProcess::Closed { think_secs: 0.02 };
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert_eq!(t.completed, 1500, "closed loop self-clocks: no shedding");
        assert_eq!(t.rejected, 0);
        assert!(
            t.meta_hit_rate > 0.1,
            "small population over a small catalog re-hits its metadata \
             cache (got {})",
            t.meta_hit_rate
        );
        assert!(t.conn_hit_rate > 0.5, "node-pair connections get reused");
    }

    #[test]
    fn overload_sheds_but_serves_every_tenant() {
        // 8 nodes cannot serve 50k rps of multi-MB objects: bounded
        // queues must shed, and round-robin service must keep both
        // tenants progressing.
        let spec = small_spec(3000, 50_000.0);
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert!(t.rejected > 0, "overload must shed");
        for slo in &t.tenants {
            assert!(slo.completed > 0, "tenant {} starved", slo.name);
        }
        assert!(t.peak_queue > 0);
    }

    #[test]
    fn crash_reroutes_to_surviving_replicas() {
        let mut spec = small_spec(2000, 400.0);
        spec.faults.push(FaultSpec::SlaveCrash {
            at_secs: 1.0,
            node: 1,
        });
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&spec).unwrap();
        assert_eq!(a, b, "faulted runs stay deterministic");
        let t = traffic(&a);
        assert_eq!(a.nodes_crashed, 1);
        assert!(t.reassignments > 0, "in-flight work must re-route");
        assert_eq!(t.completed + t.rejected + t.unavailable, 2000);
        assert!(
            t.completed > 1500,
            "rack-diverse replicas keep most data serveable ({})",
            t.completed
        );
        assert_eq!(t.unavailable, 0, "one crash never exhausts the retry budget");
    }

    #[test]
    fn brownout_raises_latency() {
        let mut spec = small_spec(1500, 300.0);
        let clean = run_scenario(&spec).unwrap();
        spec.faults.push(FaultSpec::LinkDegrade {
            at_secs: 0.0,
            duration_secs: f64::INFINITY,
            site: 0,
            factor: 0.02,
        });
        let braked = run_scenario(&spec).unwrap();
        let (c, d) = (traffic(&clean), traffic(&braked));
        assert!(
            d.tenants[0].p99_ms > c.tenants[0].p99_ms,
            "choked uplink must show in p99: {} vs {}",
            d.tenants[0].p99_ms,
            c.tenants[0].p99_ms
        );
    }

    #[test]
    fn writes_replicate_in_background() {
        let mut spec = small_spec(500, 200.0);
        spec.traffic.as_mut().unwrap().tenants = vec![TenantSpec {
            name: "ingest".into(),
            weight: 1.0,
            write_fraction: 1.0,
            object_bytes: 2.0e6,
        }];
        let r = run_scenario(&spec).unwrap();
        let t = traffic(&r);
        assert!(t.completed > 0);
        assert!(
            t.replica_gbytes > 0.0,
            "completed writes must copy to the partner replica"
        );
    }

    #[test]
    fn straggler_slows_its_slaves_service() {
        let mut spec = small_spec(1500, 300.0);
        let clean = run_scenario(&spec).unwrap();
        for node in 0..4 {
            spec.faults.push(FaultSpec::Straggler { node, factor: 0.1 });
        }
        let slowed = run_scenario(&spec).unwrap();
        assert!(
            traffic(&slowed).tenants[0].p99_ms > traffic(&clean).tenants[0].p99_ms,
            "slow disks must show in tail latency"
        );
    }

    #[test]
    fn scenario_name_is_preserved() {
        let spec = small_spec(200, 100.0);
        let r = run_scenario(&spec).unwrap();
        assert_eq!(r.name, "traffic-test");
        assert_eq!(r.workload, "traffic");
    }
}

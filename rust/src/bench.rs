//! From-scratch micro/macro benchmark harness (no `criterion` offline).
//!
//! Two layers:
//!   * `time_fn` — warmup + timed iterations with mean/std/min, for the
//!     hot-path microbenches (`bench_micro`);
//!   * `Report` — aligned paper-style tables comparing "paper" vs
//!     "measured" rows with a ratio column, used by every table/figure
//!     bench.  `Report::check_band` encodes the reproduction criterion
//!     (shape must hold even when absolute numbers differ).

use crate::util::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// Result of timing one closure.
#[derive(Clone, Debug)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub secs: Summary,
}

impl Timing {
    pub fn per_iter_ms(&self) -> f64 {
        self.secs.mean * 1e3
    }
}

/// Time `f` for `iters` measured runs after `warmup` unmeasured ones.
/// The closure's output is black-boxed to keep the optimizer honest.
pub fn time_fn<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing {
        name: name.to_string(),
        iters,
        secs: Summary::of(&samples).unwrap(),
    }
}

/// Optimizer fence (std::hint::black_box is stable since 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a `Timing` in the standard one-line format.
pub fn print_timing(t: &Timing) {
    println!(
        "{:<44} {:>6} iters  mean {:>10.4} ms  min {:>10.4} ms  p99 {:>10.4} ms",
        t.name,
        t.iters,
        t.secs.mean * 1e3,
        t.secs.min * 1e3,
        t.secs.p99 * 1e3
    );
}

/// Machine-readable bench emission: a flat JSON object written to
/// `BENCH_<name>.json` at the repo root, so the perf trajectory can be
/// tracked across PRs (and uploaded as a CI artifact) without a serde
/// dependency.  Keys keep insertion order; values are numbers or
/// strings only.
pub struct BenchJson {
    name: String,
    fields: Vec<(String, String)>,
}

impl BenchJson {
    pub fn new(name: &str) -> BenchJson {
        BenchJson {
            name: name.to_string(),
            fields: Vec::new(),
        }
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        // f64 Display is shortest-roundtrip: stable and valid JSON for
        // finite values; non-finite becomes null.
        let rendered = if v.is_finite() {
            format!("{v}")
        } else {
            "null".to_string()
        };
        self.fields.push((key.to_string(), rendered));
        self
    }

    pub fn int(&mut self, key: &str, v: u64) -> &mut Self {
        self.fields.push((key.to_string(), format!("{v}")));
        self
    }

    pub fn text(&mut self, key: &str, v: &str) -> &mut Self {
        let escaped = v.replace('\\', "\\\\").replace('"', "\\\"");
        self.fields.push((key.to_string(), format!("\"{escaped}\"")));
        self
    }

    /// Embed a pre-rendered JSON value (array or object) under `key` —
    /// how `bench_sweep` folds the SweepReport's per-point record array
    /// into the flat trajectory file without a serde dependency.  The
    /// caller owns the validity of `rendered_json`.
    pub fn raw(&mut self, key: &str, rendered_json: &str) -> &mut Self {
        self.fields.push((key.to_string(), rendered_json.to_string()));
        self
    }

    pub fn render(&self) -> String {
        let body: Vec<String> = self
            .fields
            .iter()
            .map(|(k, v)| format!("\"{k}\": {v}"))
            .collect();
        format!("{{{}}}\n", body.join(", "))
    }

    /// Write `BENCH_<name>.json` at the repo root (CARGO_MANIFEST_DIR
    /// when run through cargo, the working directory otherwise) and
    /// return the path.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let base = std::env::var("CARGO_MANIFEST_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("."));
        let path = base.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.render())?;
        Ok(path)
    }
}

/// A paper-vs-measured comparison table.
pub struct Report {
    title: String,
    columns: Vec<String>,
    rows: Vec<(String, Vec<f64>)>,
    notes: Vec<String>,
    deviations: Vec<String>,
}

impl Report {
    pub fn new(title: &str, columns: &[String]) -> Self {
        Self {
            title: title.to_string(),
            columns: columns.to_vec(),
            rows: Vec::new(),
            notes: Vec::new(),
            deviations: Vec::new(),
        }
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) -> &mut Self {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
        self
    }

    pub fn note(&mut self, s: &str) -> &mut Self {
        self.notes.push(s.to_string());
        self
    }

    /// Record a reproduction check: each measured value must lie within
    /// `tol` relative error of the paper value, element-wise. Failures
    /// are collected (not fatal) and surfaced in `render()` plus
    /// `deviation_count()` so benches can exit non-zero if desired.
    pub fn check_band(&mut self, what: &str, paper: &[f64], measured: &[f64], tol: f64) {
        assert_eq!(paper.len(), measured.len());
        for (i, (&p, &m)) in paper.iter().zip(measured).enumerate() {
            if p == 0.0 {
                continue;
            }
            let rel = (m - p).abs() / p.abs();
            if rel > tol {
                self.deviations.push(format!(
                    "{what}[{i}]: paper {p:.1} vs measured {m:.1} ({:+.0}% > ±{:.0}%)",
                    100.0 * (m - p) / p,
                    tol * 100.0
                ));
            }
        }
    }

    pub fn deviation_count(&self) -> usize {
        self.deviations.len()
    }

    pub fn render(&self) -> String {
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        let col_w = self
            .columns
            .iter()
            .map(|c| c.len().max(8))
            .collect::<Vec<_>>();
        let mut s = format!("\n=== {} ===\n", self.title);
        s.push_str(&format!("{:<label_w$}", ""));
        for (c, w) in self.columns.iter().zip(&col_w) {
            s.push_str(&format!(" {c:>w$}"));
        }
        s.push('\n');
        for (label, vals) in &self.rows {
            s.push_str(&format!("{label:<label_w$}"));
            for (v, w) in vals.iter().zip(&col_w) {
                if v.abs() >= 1000.0 {
                    s.push_str(&format!(" {v:>w$.0}"));
                } else if v.abs() >= 10.0 {
                    s.push_str(&format!(" {v:>w$.1}"));
                } else {
                    s.push_str(&format!(" {v:>w$.2}"));
                }
            }
            s.push('\n');
        }
        for n in &self.notes {
            s.push_str(&format!("  note: {n}\n"));
        }
        if self.deviations.is_empty() {
            s.push_str("  reproduction check: all values within band\n");
        } else {
            for d in &self.deviations {
                s.push_str(&format!("  DEVIATION: {d}\n"));
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_fn_collects_samples() {
        let t = time_fn("noop-ish", 2, 5, || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(t.iters, 5);
        assert!(t.secs.mean >= 0.0);
        assert!(t.per_iter_ms() >= 0.0);
    }

    #[test]
    fn report_renders_and_checks_bands() {
        let mut r = Report::new("Table X", &["1".into(), "2".into()]);
        r.row("paper", vec![100.0, 200.0]);
        r.row("measured", vec![104.0, 290.0]);
        r.check_band("sort", &[100.0, 200.0], &[104.0, 290.0], 0.25);
        assert_eq!(r.deviation_count(), 1);
        let text = r.render();
        assert!(text.contains("Table X"));
        assert!(text.contains("DEVIATION"));
        let mut ok = Report::new("T", &["a".into()]);
        ok.row("r", vec![1.0]);
        ok.check_band("x", &[1.0], &[1.1], 0.25);
        assert!(ok.render().contains("within band"));
    }

    #[test]
    fn bench_json_renders_flat_object() {
        let mut j = BenchJson::new("unit");
        j.int("events", 42)
            .num("wall_ms", 1.5)
            .num("bad", f64::INFINITY)
            .text("name", "scale\"128\"");
        assert_eq!(
            j.render(),
            "{\"events\": 42, \"wall_ms\": 1.5, \"bad\": null, \
             \"name\": \"scale\\\"128\\\"\"}\n"
        );
    }

    #[test]
    fn bench_json_embeds_raw_values() {
        let mut j = BenchJson::new("unit");
        j.int("points", 2)
            .raw("records", "[{\"index\": 0}, {\"index\": 1}]");
        assert_eq!(
            j.render(),
            "{\"points\": 2, \"records\": [{\"index\": 0}, {\"index\": 1}]}\n"
        );
    }

    #[test]
    #[should_panic]
    fn report_rejects_ragged_rows() {
        let mut r = Report::new("T", &["a".into(), "b".into()]);
        r.row("bad", vec![1.0]);
    }
}

//! CPU model: a pool of identical cores scheduling non-preemptive tasks.
//!
//! The paper notes Sphere's Terasort used 1 of 4 cores per node while
//! Hadoop used all 4 — the core-count asymmetry is part of the
//! experimental record, so the model makes it explicit.

#[derive(Clone, Debug)]
pub struct CpuPool {
    /// Per-core time at which the core becomes free.
    free_at: Vec<f64>,
    /// Total busy seconds across cores.
    pub busy_secs: f64,
}

impl CpuPool {
    pub fn new(cores: usize) -> Self {
        assert!(cores > 0);
        Self {
            free_at: vec![0.0; cores],
            busy_secs: 0.0,
        }
    }

    pub fn cores(&self) -> usize {
        self.free_at.len()
    }

    /// Submit a task of `secs` CPU time at `now`; it runs on the earliest
    /// available core. Returns its completion time.
    pub fn submit(&mut self, now: f64, secs: f64) -> f64 {
        assert!(secs >= 0.0);
        let (idx, _) = self
            .free_at
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(a.0.cmp(&b.0)))
            .unwrap();
        let start = now.max(self.free_at[idx]);
        self.free_at[idx] = start + secs;
        self.busy_secs += secs;
        self.free_at[idx]
    }

    /// Completion time of a perfectly parallelizable chunk of `total_secs`
    /// CPU-seconds started at `now` when the pool is otherwise idle.
    pub fn submit_parallel(&mut self, now: f64, total_secs: f64) -> f64 {
        let per_core = total_secs / self.cores() as f64;
        let mut last = now;
        for _ in 0..self.cores() {
            last = last.max(self.submit(now, per_core));
        }
        last
    }

    pub fn free_at_earliest(&self) -> f64 {
        self.free_at
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
    }

    pub fn utilization(&self, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (self.busy_secs / (now * self.cores() as f64)).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tasks_fill_cores_then_queue() {
        let mut p = CpuPool::new(2);
        assert_eq!(p.submit(0.0, 4.0), 4.0); // core 0
        assert_eq!(p.submit(0.0, 3.0), 3.0); // core 1
        assert_eq!(p.submit(0.0, 2.0), 5.0); // queues behind core 1
        assert_eq!(p.cores(), 2);
    }

    #[test]
    fn parallel_chunk_splits_evenly() {
        let mut p = CpuPool::new(4);
        let done = p.submit_parallel(10.0, 8.0);
        assert!((done - 12.0).abs() < 1e-12);
        assert!((p.utilization(12.0) - 8.0 / 48.0).abs() < 1e-12);
    }

    #[test]
    fn later_submission_starts_at_now() {
        let mut p = CpuPool::new(1);
        p.submit(0.0, 1.0);
        assert_eq!(p.submit(5.0, 1.0), 6.0);
    }
}

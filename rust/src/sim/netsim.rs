//! Flow-level network simulator with max-min fair bandwidth sharing.
//!
//! The paper's WAN results hinge on how transport protocols share long
//! fat pipes: UDT (rate-based AIMD) sustains a high fraction of a
//! 10 Gb/s path regardless of RTT, while TCP Reno's window growth caps
//! throughput at roughly `MSS/RTT * 1/sqrt(loss)` (the Mathis model).
//! We model the network at *flow* granularity: each flow has a path
//! (sequence of directed links), a remaining byte count, and a protocol
//! rate cap computed by `transport::{udt,tcp}`.  Whenever the active
//! flow set changes, rates are re-assigned by progressive filling
//! (max-min fairness subject to per-flow caps), the textbook model for
//! long-lived bulk flows.
//!
//! Invariants (property-tested in rust/tests/props_netsim.rs):
//!   * no link carries more than its capacity;
//!   * allocation is Pareto-optimal: every unfrozen flow is bottlenecked
//!     by either its cap or a saturated link;
//!   * flow rates are monotone non-increasing in added contention.

use std::collections::BTreeMap;

/// Directed link with a fixed capacity in bytes/second.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

/// Active flow handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
struct Link {
    capacity: f64, // bytes/s
}

#[derive(Clone, Debug)]
struct Flow {
    path: Vec<LinkId>,
    remaining: f64, // bytes
    rate_cap: f64,  // protocol/application ceiling, bytes/s
    rate: f64,      // currently allocated, bytes/s
}

/// The simulator. Time is advanced externally (`advance_to`); the owner
/// interleaves it with an `EventQueue` via `next_completion`.
///
/// Flows live in a `BTreeMap` keyed by monotonically increasing ids:
/// iteration order IS id order, so the allocator needs no per-query
/// key sort (the old HashMap + sort cost dominated at 128-node
/// scenario scale, where one shuffle wave is >10k flows).
#[derive(Default)]
pub struct NetSim {
    links: Vec<Link>,
    flows: BTreeMap<FlowId, Flow>,
    next_flow: u64,
    now: f64,
    rates_dirty: bool,
    /// Memoized `next_completion` answer.  Completion times are
    /// absolute and rates only change when the flow/link set does, so
    /// the answer stays valid across `advance_to` calls that complete
    /// nothing — which is every event-loop iteration driven by a
    /// non-network event (the traffic engine's arrivals/dispatches).
    /// `None` = stale; recomputed on demand.
    completion_cache: Option<Option<(f64, FlowId)>>,
    /// Total bytes delivered, for throughput reporting.
    pub delivered_bytes: f64,
}

impl NetSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the link table for a known topology (scenario engine:
    /// 2 links per node + 2 per rack + 2 per site).
    pub fn with_capacity(links: usize) -> Self {
        Self {
            links: Vec::with_capacity(links),
            ..Self::default()
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn add_link(&mut self, capacity_bytes_per_sec: f64) -> LinkId {
        assert!(capacity_bytes_per_sec > 0.0);
        self.links.push(Link {
            capacity: capacity_bytes_per_sec,
        });
        LinkId(self.links.len() - 1)
    }

    pub fn link_capacity(&self, l: LinkId) -> f64 {
        self.links[l.0].capacity
    }

    /// Number of links added so far (ids are dense: 0..link_count()).
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Change a link's capacity in place (fault injection: degradation
    /// and repair). Active flows are re-allocated on the next query.
    pub fn set_link_capacity(&mut self, l: LinkId, capacity_bytes_per_sec: f64) {
        assert!(capacity_bytes_per_sec > 0.0);
        self.links[l.0].capacity = capacity_bytes_per_sec;
        self.mark_dirty();
    }

    /// Rates (and therefore completion times) must be recomputed.
    fn mark_dirty(&mut self) {
        self.rates_dirty = true;
        self.completion_cache = None;
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Start a flow of `bytes` along `path`, throttled at `rate_cap`
    /// (bytes/s) by its transport protocol / application source.
    /// An empty path models a node-local copy: only the cap applies.
    pub fn start_flow(&mut self, path: &[LinkId], bytes: f64, rate_cap: f64) -> FlowId {
        assert!(bytes > 0.0, "flow must carry bytes");
        assert!(rate_cap > 0.0, "rate cap must be positive");
        for l in path {
            assert!(l.0 < self.links.len(), "unknown link {l:?}");
        }
        let id = FlowId(self.next_flow);
        self.next_flow += 1;
        self.flows.insert(
            id,
            Flow {
                path: path.to_vec(),
                remaining: bytes,
                rate_cap,
                rate: 0.0,
            },
        );
        self.mark_dirty();
        id
    }

    /// Max-min fair progressive filling with per-flow rate caps.
    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        let nl = self.links.len();
        let mut remaining_cap: Vec<f64> = self.links.iter().map(|l| l.capacity).collect();
        let mut unfrozen_count: Vec<usize> = vec![0; nl];

        // BTreeMap keys iterate in id order: deterministic without a sort.
        let ids: Vec<FlowId> = self.flows.keys().copied().collect();
        let mut frozen = vec![false; ids.len()];
        for id in &ids {
            for l in &self.flows[id].path {
                unfrozen_count[l.0] += 1;
            }
        }
        let mut unfrozen = ids.len();

        while unfrozen > 0 {
            // Fair share offered by the most contended link.
            let mut min_share = f64::INFINITY;
            for i in 0..nl {
                if unfrozen_count[i] > 0 {
                    min_share = min_share.min(remaining_cap[i] / unfrozen_count[i] as f64);
                }
            }
            // Flows not crossing any link are bounded only by their caps.
            // Freeze every unfrozen flow whose cap is <= the share (they
            // can't use their full fair share), else freeze the flows on
            // the bottleneck link(s) at the share.
            let mut froze_capped = false;
            for (k, id) in ids.iter().enumerate() {
                if frozen[k] {
                    continue;
                }
                let cap = self.flows[id].rate_cap;
                let effective_share = if self.flows[id].path.is_empty() {
                    f64::INFINITY
                } else {
                    min_share
                };
                if cap <= effective_share {
                    Self::freeze(
                        &mut self.flows,
                        &mut remaining_cap,
                        &mut unfrozen_count,
                        id,
                        cap,
                    );
                    frozen[k] = true;
                    unfrozen -= 1;
                    froze_capped = true;
                }
            }
            if froze_capped {
                continue;
            }
            debug_assert!(min_share.is_finite(), "uncapped pathless flow");
            // Freeze flows on saturating links at the fair share.
            let mut froze_any = false;
            for i in 0..nl {
                if unfrozen_count[i] > 0
                    && (remaining_cap[i] / unfrozen_count[i] as f64) <= min_share * (1.0 + 1e-12)
                {
                    for (k, id) in ids.iter().enumerate() {
                        if !frozen[k] && self.flows[id].path.iter().any(|l| l.0 == i) {
                            Self::freeze(
                                &mut self.flows,
                                &mut remaining_cap,
                                &mut unfrozen_count,
                                id,
                                min_share,
                            );
                            frozen[k] = true;
                            unfrozen -= 1;
                            froze_any = true;
                        }
                    }
                }
            }
            debug_assert!(froze_any, "progressive filling made no progress");
            if !froze_any {
                break; // defensive: avoid an infinite loop in release
            }
        }
    }

    fn freeze(
        flows: &mut BTreeMap<FlowId, Flow>,
        remaining_cap: &mut [f64],
        unfrozen_count: &mut [usize],
        id: &FlowId,
        rate: f64,
    ) {
        let f = flows.get_mut(id).unwrap();
        f.rate = rate;
        for l in &f.path {
            remaining_cap[l.0] = (remaining_cap[l.0] - rate).max(0.0);
            unfrozen_count[l.0] -= 1;
        }
    }

    fn ensure_rates(&mut self) {
        if self.rates_dirty {
            self.recompute_rates();
        }
    }

    /// Current allocated rate of a flow (bytes/s).
    pub fn flow_rate(&mut self, id: FlowId) -> f64 {
        self.ensure_rates();
        self.flows[&id].rate
    }

    pub fn flow_remaining(&self, id: FlowId) -> f64 {
        self.flows[&id].remaining
    }

    /// Abort an active flow (fault injection: a crashed receiver or
    /// sender). Returns the undelivered byte count so the caller can
    /// re-send it elsewhere.
    pub fn cancel_flow(&mut self, id: FlowId) -> f64 {
        self.try_cancel_flow(id).expect("cancel of unknown flow")
    }

    /// Like `cancel_flow`, but tolerates an id that is no longer
    /// active — e.g. a speculation loser that completed in the same
    /// `advance_to` batch as the winner cancelling it.  Returns the
    /// undelivered bytes, or `None` when the flow is gone.
    pub fn try_cancel_flow(&mut self, id: FlowId) -> Option<f64> {
        let f = self.flows.remove(&id)?;
        self.mark_dirty();
        Some(f.remaining)
    }

    /// (time, flow) of the earliest completion among active flows, given
    /// current rates — or None if no flows are active.  Memoized: the
    /// linear scan only reruns after the flow/link set changed.
    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        self.ensure_rates();
        if let Some(cached) = self.completion_cache {
            return cached;
        }
        let mut best: Option<(f64, FlowId)> = None;
        for (&id, f) in &self.flows {
            if f.rate <= 0.0 {
                continue;
            }
            let t = self.now + f.remaining / f.rate;
            if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                best = Some((t, id));
            }
        }
        self.completion_cache = Some(best);
        best
    }

    /// Advance virtual time to `t`, progressing all flows at their
    /// current rates. Flows that hit zero are completed and returned.
    pub fn advance_to(&mut self, t: f64) -> Vec<FlowId> {
        assert!(t >= self.now - 1e-9, "time went backwards");
        self.ensure_rates();
        let dt = (t - self.now).max(0.0);
        self.now = t;
        let mut done = Vec::new();
        for (&id, f) in self.flows.iter_mut() {
            let moved = (f.rate * dt).min(f.remaining);
            f.remaining -= moved;
            self.delivered_bytes += moved;
            if f.remaining <= 1e-6 {
                self.delivered_bytes += f.remaining;
                done.push(id);
            }
        }
        if !done.is_empty() {
            self.mark_dirty();
            for id in &done {
                self.flows.remove(id);
            }
        }
        done
    }

    /// Drive the network alone until all flows finish; returns the
    /// completion time of the last one. (Helper for tests/benches that
    /// have no interleaved discrete events.)
    pub fn run_to_idle(&mut self) -> f64 {
        while let Some((t, _)) = self.next_completion() {
            self.advance_to(t);
        }
        self.now
    }

    /// Sum of allocated rates crossing a link (<= capacity; for tests).
    pub fn link_load(&mut self, l: LinkId) -> f64 {
        self.ensure_rates();
        self.flows
            .values()
            .filter(|f| f.path.contains(&l))
            .map(|f| f.rate)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_min_of_cap_and_link() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1000.0, 250.0);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9);
        let f2 = net.start_flow(&[l], 1000.0, 30.0);
        assert!((net.flow_rate(f2) - 30.0).abs() < 1e-9);
        // f gets the rest
        assert!((net.flow_rate(f) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn equal_flows_share_equally() {
        let mut net = NetSim::new();
        let l = net.add_link(90.0);
        let fs: Vec<FlowId> = (0..3).map(|_| net.start_flow(&[l], 900.0, 1e9)).collect();
        for f in &fs {
            assert!((net.flow_rate(*f) - 30.0).abs() < 1e-9);
        }
        assert!(net.link_load(l) <= 90.0 + 1e-9);
    }

    #[test]
    fn capped_flow_releases_bandwidth() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let slow = net.start_flow(&[l], 1e6, 10.0);
        let fast = net.start_flow(&[l], 1e6, 1e9);
        assert!((net.flow_rate(slow) - 10.0).abs() < 1e-9);
        assert!((net.flow_rate(fast) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn multi_link_path_bottlenecked_by_narrowest() {
        let mut net = NetSim::new();
        let wide = net.add_link(1000.0);
        let narrow = net.add_link(50.0);
        let f = net.start_flow(&[wide, narrow], 500.0, 1e9);
        assert!((net.flow_rate(f) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn completion_times_and_rate_rebalance() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let _a = net.start_flow(&[l], 100.0, 1e9); // at 50 B/s -> 2 s
        let b = net.start_flow(&[l], 300.0, 1e9);
        let (t1, _) = net.next_completion().unwrap();
        assert!((t1 - 2.0).abs() < 1e-9);
        let done = net.advance_to(t1);
        assert_eq!(done.len(), 1);
        // b then speeds up to 100 B/s with 200 bytes left -> +2 s
        let (t2, id2) = net.next_completion().unwrap();
        assert_eq!(id2, b);
        assert!((t2 - 4.0).abs() < 1e-9);
        net.advance_to(t2);
        assert_eq!(net.active_flows(), 0);
        assert!((net.delivered_bytes - 400.0).abs() < 1e-6);
    }

    #[test]
    fn pathless_flow_runs_at_cap() {
        let mut net = NetSim::new();
        let f = net.start_flow(&[], 100.0, 25.0);
        assert!((net.flow_rate(f) - 25.0).abs() < 1e-12);
        assert!((net.run_to_idle() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn cross_traffic_max_min() {
        // Two links A, B. Flow1 uses A+B, flow2 uses A, flow3 uses B.
        // cap(A)=100, cap(B)=60: flow1 and flow3 split B at 30 each;
        // flow2 then gets 70 on A.
        let mut net = NetSim::new();
        let a = net.add_link(100.0);
        let b = net.add_link(60.0);
        let f1 = net.start_flow(&[a, b], 1e6, 1e9);
        let f2 = net.start_flow(&[a], 1e6, 1e9);
        let f3 = net.start_flow(&[b], 1e6, 1e9);
        assert!((net.flow_rate(f1) - 30.0).abs() < 1e-9);
        assert!((net.flow_rate(f3) - 30.0).abs() < 1e-9);
        assert!((net.flow_rate(f2) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn link_degradation_reroutes_rates() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1e6, 1e9);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9);
        net.set_link_capacity(l, 25.0);
        assert!((net.flow_rate(f) - 25.0).abs() < 1e-9, "degraded");
        net.set_link_capacity(l, 100.0);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9, "repaired");
    }

    #[test]
    fn cancel_flow_returns_undelivered_bytes() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let a = net.start_flow(&[l], 1000.0, 1e9);
        let b = net.start_flow(&[l], 1000.0, 1e9);
        net.advance_to(2.0); // each moved 100 bytes at 50 B/s
        let left = net.cancel_flow(a);
        assert!((left - 900.0).abs() < 1e-6);
        // survivor reclaims the full link
        assert!((net.flow_rate(b) - 100.0).abs() < 1e-9);
        assert_eq!(net.active_flows(), 1);
    }

    #[test]
    fn try_cancel_tolerates_finished_flows() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let a = net.start_flow(&[l], 100.0, 1e9);
        let b = net.start_flow(&[l], 1000.0, 1e9);
        assert!(net.try_cancel_flow(a).is_some(), "active flow cancels");
        assert!(net.try_cancel_flow(a).is_none(), "second cancel is a no-op");
        net.run_to_idle();
        assert!(net.try_cancel_flow(b).is_none(), "completed flow is gone");
    }

    #[test]
    fn next_completion_memo_survives_idle_advances_and_invalidates() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1000.0, 1e9); // completes at t=10
        let first = net.next_completion().unwrap();
        assert_eq!(first.1, f);
        // Advancing without completing anything must not change the
        // answer (this is the memoized path).
        net.advance_to(3.0);
        assert_eq!(net.next_completion().unwrap(), first);
        // A new flow invalidates: it shares the link, finishes first.
        let short = net.start_flow(&[l], 10.0, 1e9);
        let (t, id) = net.next_completion().unwrap();
        assert_eq!(id, short);
        assert!((t - 3.2).abs() < 1e-9, "50 B/s share, 10 bytes: {t}");
        // Capacity changes invalidate too.
        net.set_link_capacity(l, 50.0);
        let (t, _) = net.next_completion().unwrap();
        assert!((t - 3.4).abs() < 1e-9, "25 B/s share after degrade: {t}");
    }

    #[test]
    fn run_to_idle_conserves_bytes() {
        let mut net = NetSim::new();
        let l = net.add_link(10.0);
        for i in 1..=5 {
            net.start_flow(&[l], 10.0 * i as f64, 1e9);
        }
        net.run_to_idle();
        assert!((net.delivered_bytes - 150.0).abs() < 1e-3);
        assert_eq!(net.active_flows(), 0);
    }
}

//! Flow-level network simulator with max-min fair bandwidth sharing.
//!
//! The paper's WAN results hinge on how transport protocols share long
//! fat pipes: UDT (rate-based AIMD) sustains a high fraction of a
//! 10 Gb/s path regardless of RTT, while TCP Reno's window growth caps
//! throughput at roughly `MSS/RTT * 1/sqrt(loss)` (the Mathis model).
//! We model the network at *flow* granularity: each flow has a path
//! (sequence of directed links), a remaining byte count, and a protocol
//! rate cap computed by `transport::{udt,tcp}`.  Whenever the active
//! flow set changes, rates are re-assigned by progressive filling
//! (max-min fairness subject to per-flow caps), the textbook model for
//! long-lived bulk flows.
//!
//! **Incremental recomputation** (DESIGN.md §14): a flow-set or
//! capacity change dirties only the links it touches.  Before rates are
//! next read, the affected *connected component* — the closure of
//! links and flows reachable from the dirty links through shared path
//! membership — is re-filled from scratch; every other flow keeps its
//! rate.  Max-min allocation is independent across disjoint components
//! (no shared link, no interaction), so the result matches the global
//! algorithm; the global pass is retained verbatim as [`NetSim::oracle_rates`]
//! and the equivalence is property-tested in rust/tests/props_netsim.rs.
//! The sole divergence is adversarial near-ties across components
//! within the filling loop's 1e-12 tie epsilon, bounded well under the
//! property suite's 1e-9 tolerance.
//!
//! Invariants (property-tested in rust/tests/props_netsim.rs):
//!   * no link carries more than its capacity;
//!   * allocation is Pareto-optimal: every unfrozen flow is bottlenecked
//!     by either its cap or a saturated link;
//!   * incremental rates equal the retained full-recompute oracle.

use std::collections::VecDeque;

/// Directed link with a fixed capacity in bytes/second.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

/// Active flow handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct FlowId(pub u64);

#[derive(Clone, Debug)]
struct Link {
    capacity: f64, // bytes/s
}

#[derive(Clone, Debug)]
struct Flow {
    path: Vec<LinkId>,
    remaining: f64, // bytes
    rate_cap: f64,  // protocol/application ceiling, bytes/s
    rate: f64,      // currently allocated, bytes/s
    /// Visit stamp for component discovery (O(1) membership without a
    /// clearable side table).
    seen: u64,
}

/// Arena of live flows keyed by monotonically increasing ids.
///
/// Ids are dense-ish: slot = id - base, where `base` advances as the
/// oldest flows retire.  Lookup, insert and remove are O(1) (the old
/// `BTreeMap` paid a tree walk per event at 128-node scale, where one
/// shuffle wave is >10k flows), and front-to-back iteration IS id
/// order — the allocator's determinism contract needs no sort.
#[derive(Default)]
struct FlowTable {
    slots: VecDeque<Option<Flow>>,
    base: u64,
    live: usize,
}

impl FlowTable {
    /// Next id that `push` will assign.
    fn next_id(&self) -> u64 {
        self.base + self.slots.len() as u64
    }

    fn push(&mut self, f: Flow) -> FlowId {
        let id = FlowId(self.next_id());
        self.slots.push_back(Some(f));
        self.live += 1;
        id
    }

    fn slot_of(&self, id: FlowId) -> Option<usize> {
        let idx = id.0.checked_sub(self.base)? as usize;
        (idx < self.slots.len()).then_some(idx)
    }

    fn get(&self, id: FlowId) -> Option<&Flow> {
        self.slots.get(self.slot_of(id)?)?.as_ref()
    }

    fn get_mut(&mut self, id: FlowId) -> Option<&mut Flow> {
        let idx = self.slot_of(id)?;
        self.slots.get_mut(idx)?.as_mut()
    }

    fn remove(&mut self, id: FlowId) -> Option<Flow> {
        let idx = self.slot_of(id)?;
        let f = self.slots.get_mut(idx)?.take()?;
        self.live -= 1;
        // Compact retired slots off the front so the window tracks the
        // live id range instead of growing with total churn.
        while matches!(self.slots.front(), Some(None)) {
            self.slots.pop_front();
            self.base += 1;
        }
        if self.slots.is_empty() {
            // Keep ids monotone: base is now exactly next_id.
            debug_assert_eq!(self.live, 0);
        }
        Some(f)
    }

    fn len(&self) -> usize {
        self.live
    }

    /// Live flows in id order.
    fn iter(&self) -> impl Iterator<Item = (FlowId, &Flow)> {
        let base = self.base;
        self.slots
            .iter()
            .enumerate()
            .filter_map(move |(i, s)| s.as_ref().map(|f| (FlowId(base + i as u64), f)))
    }

    fn iter_mut(&mut self) -> impl Iterator<Item = (FlowId, &mut Flow)> {
        let base = self.base;
        self.slots
            .iter_mut()
            .enumerate()
            .filter_map(move |(i, s)| s.as_mut().map(|f| (FlowId(base + i as u64), f)))
    }
}

/// Wall-clock-free self-profiling counters for the incremental
/// fair-share hot path (DESIGN.md §14/§15): how often each recompute
/// path ran and how big the dirty-BFS components were.  Surfaced in
/// `BENCH_engine.json` by benches/bench_engine.rs; never part of a
/// deterministic report.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct NetProfile {
    /// Component-scoped (dirty-BFS) recomputations performed.
    pub dirty_recomputes: u64,
    /// Whole-flow-set recomputations (initial fill / bench baseline).
    pub full_recomputes: u64,
    /// Sum of dirty-component sizes (flows), for the mean.
    pub comp_flows_total: u64,
    /// Largest dirty component seen (flows).
    pub comp_flows_max: u64,
}

impl NetProfile {
    /// Mean flows per dirty-BFS component.
    pub fn comp_flows_mean(&self) -> f64 {
        if self.dirty_recomputes == 0 {
            0.0
        } else {
            self.comp_flows_total as f64 / self.dirty_recomputes as f64
        }
    }
}

/// The simulator. Time is advanced externally (`advance_to`); the owner
/// interleaves it with an `EventQueue` via `next_completion`.
#[derive(Default)]
pub struct NetSim {
    links: Vec<Link>,
    flows: FlowTable,
    /// Per-link membership: which live flows cross each link
    /// (unordered; used only for component discovery and counting).
    link_flows: Vec<Vec<FlowId>>,
    /// Links whose flow set or capacity changed since the last rate
    /// computation (deduplicated via `link_dirty`).
    dirty_links: Vec<usize>,
    link_dirty: Vec<bool>,
    rates_dirty: bool,
    /// Bench baseline knob: when set, every change re-fills every flow
    /// (the pre-incremental behavior). See benches/bench_engine.rs.
    full_recompute: bool,
    now: f64,
    /// Memoized `next_completion` answer.  Completion times are
    /// absolute and rates only change when the flow/link set does, so
    /// the answer stays valid across `advance_to` calls that complete
    /// nothing — which is every event-loop iteration driven by a
    /// non-network event (the traffic engine's arrivals/dispatches).
    /// `None` = stale; recomputed on demand.
    completion_cache: Option<Option<(f64, FlowId)>>,
    /// Total bytes delivered, for throughput reporting.
    pub delivered_bytes: f64,
    // Reusable scratch for the progressive filler (sized to the link
    // table; entries are re-initialized per component before use).
    scratch_cap: Vec<f64>,
    scratch_cnt: Vec<usize>,
    scratch_link_seen: Vec<bool>,
    /// Monotone visit stamp; bumped once per component discovery.
    stamp: u64,
    /// Self-profiling counters (see [`NetProfile`]).
    profile: NetProfile,
}

impl NetSim {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-size the link table for a known topology (scenario engine:
    /// 2 links per node + 2 per rack + 2 per site).
    pub fn with_capacity(links: usize) -> Self {
        Self {
            links: Vec::with_capacity(links),
            link_flows: Vec::with_capacity(links),
            ..Self::default()
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn add_link(&mut self, capacity_bytes_per_sec: f64) -> LinkId {
        assert!(capacity_bytes_per_sec > 0.0);
        self.links.push(Link {
            capacity: capacity_bytes_per_sec,
        });
        self.link_flows.push(Vec::new());
        self.link_dirty.push(false);
        self.scratch_cap.push(0.0);
        self.scratch_cnt.push(0);
        self.scratch_link_seen.push(false);
        LinkId(self.links.len() - 1)
    }

    pub fn link_capacity(&self, l: LinkId) -> f64 {
        self.links[l.0].capacity
    }

    /// Number of links added so far (ids are dense: 0..link_count()).
    pub fn link_count(&self) -> usize {
        self.links.len()
    }

    /// Per-link active-flow census, indexed by `LinkId`.  The membership
    /// lists are pruned on completion and cancellation (`detach`), so
    /// the counts reflect exactly the flows currently crossing each
    /// link — the elastic scaler reads this once per tick to steer
    /// re-replication toward quiet NICs (DESIGN.md §16).
    pub fn link_flow_counts(&self) -> Vec<usize> {
        self.link_flows.iter().map(Vec::len).collect()
    }

    /// Change a link's capacity in place (fault injection: degradation
    /// and repair). Flows in the link's component are re-allocated on
    /// the next query.
    pub fn set_link_capacity(&mut self, l: LinkId, capacity_bytes_per_sec: f64) {
        assert!(capacity_bytes_per_sec > 0.0);
        self.links[l.0].capacity = capacity_bytes_per_sec;
        self.mark_link_dirty(l.0);
    }

    /// Disable (or re-enable) incremental recomputation.  With `true`,
    /// any change re-runs progressive filling over the whole flow set —
    /// the pre-optimization behavior, kept as the in-process baseline
    /// for benches/bench_engine.rs.  Rates are identical either way.
    pub fn set_full_recompute(&mut self, on: bool) {
        self.full_recompute = on;
    }

    fn mark_link_dirty(&mut self, l: usize) {
        if !self.link_dirty[l] {
            self.link_dirty[l] = true;
            self.dirty_links.push(l);
        }
        self.rates_dirty = true;
        self.completion_cache = None;
    }

    pub fn active_flows(&self) -> usize {
        self.flows.len()
    }

    /// Start a flow of `bytes` along `path`, throttled at `rate_cap`
    /// (bytes/s) by its transport protocol / application source.
    /// An empty path models a node-local copy: only the cap applies.
    pub fn start_flow(&mut self, path: &[LinkId], bytes: f64, rate_cap: f64) -> FlowId {
        assert!(bytes > 0.0, "flow must carry bytes");
        assert!(rate_cap > 0.0, "rate cap must be positive");
        for l in path {
            assert!(l.0 < self.links.len(), "unknown link {l:?}");
        }
        // Pathless flows never contend: they run at their cap from the
        // start and no component needs recomputing.
        let rate = if path.is_empty() { rate_cap } else { 0.0 };
        let id = self.flows.push(Flow {
            path: path.to_vec(),
            remaining: bytes,
            rate_cap,
            rate,
            seen: 0,
        });
        for l in path {
            self.link_flows[l.0].push(id);
            self.mark_link_dirty(l.0);
        }
        self.completion_cache = None;
        id
    }

    /// Forget a flow's link memberships and dirty the links it crossed
    /// (its old rate must be redistributed to its component).
    fn detach(&mut self, id: FlowId, path: &[LinkId]) {
        for l in path {
            let members = &mut self.link_flows[l.0];
            if let Some(pos) = members.iter().position(|&f| f == id) {
                members.swap_remove(pos);
            }
            self.mark_link_dirty(l.0);
        }
        self.completion_cache = None;
    }

    /// Progressive filling (max-min with per-flow caps) restricted to
    /// `ids` (flow ids, ascending) and the links they cross.  `ids`
    /// must be *closed*: every flow sharing a link with a member is a
    /// member — then full link capacities apply and the result equals
    /// the global algorithm's on those flows.
    fn fill(&mut self, ids: &[FlowId]) {
        let cap_left = &mut self.scratch_cap;
        let cnt = &mut self.scratch_cnt;
        // Links the component crosses, in index order (the tie-freeze
        // phase scans links in index order; keep that deterministic).
        // `cnt` entries are zero between fills, so first touch = new.
        let mut comp_links: Vec<usize> = Vec::new();
        for id in ids {
            for l in &self.flows.get(*id).expect("component flow exists").path {
                if cnt[l.0] == 0 {
                    comp_links.push(l.0);
                }
                cnt[l.0] += 1;
            }
        }
        comp_links.sort_unstable();
        for &l in &comp_links {
            cap_left[l] = self.links[l].capacity;
        }

        let mut frozen = vec![false; ids.len()];
        let mut unfrozen = ids.len();
        while unfrozen > 0 {
            // Fair share offered by the most contended link.
            let mut min_share = f64::INFINITY;
            for &l in &comp_links {
                if cnt[l] > 0 {
                    min_share = min_share.min(cap_left[l] / cnt[l] as f64);
                }
            }
            // Flows not crossing any link are bounded only by their caps.
            // Freeze every unfrozen flow whose cap is <= the share (they
            // can't use their full fair share), else freeze the flows on
            // the bottleneck link(s) at the share.
            let mut froze_capped = false;
            for (k, id) in ids.iter().enumerate() {
                if frozen[k] {
                    continue;
                }
                let f = self.flows.get(*id).expect("component flow exists");
                let cap = f.rate_cap;
                let effective_share = if f.path.is_empty() {
                    f64::INFINITY
                } else {
                    min_share
                };
                if cap <= effective_share {
                    let f = self.flows.get_mut(*id).expect("component flow exists");
                    f.rate = cap;
                    for l in &f.path {
                        cap_left[l.0] = (cap_left[l.0] - cap).max(0.0);
                        cnt[l.0] -= 1;
                    }
                    frozen[k] = true;
                    unfrozen -= 1;
                    froze_capped = true;
                }
            }
            if froze_capped {
                continue;
            }
            debug_assert!(min_share.is_finite(), "uncapped pathless flow");
            // Freeze flows on saturating links at the fair share.
            let mut froze_any = false;
            for &l in &comp_links {
                if cnt[l] > 0 && (cap_left[l] / cnt[l] as f64) <= min_share * (1.0 + 1e-12) {
                    for (k, id) in ids.iter().enumerate() {
                        if frozen[k] {
                            continue;
                        }
                        let f = self.flows.get_mut(*id).expect("component flow exists");
                        if f.path.iter().any(|p| p.0 == l) {
                            f.rate = min_share;
                            for p in &f.path {
                                cap_left[p.0] = (cap_left[p.0] - min_share).max(0.0);
                                cnt[p.0] -= 1;
                            }
                            frozen[k] = true;
                            unfrozen -= 1;
                            froze_any = true;
                        }
                    }
                }
            }
            debug_assert!(froze_any, "progressive filling made no progress");
            if !froze_any {
                break; // defensive: avoid an infinite loop in release
            }
        }
        // Restore the between-fills invariant (cnt all zero).  Freezing
        // each flow exactly once already zeroes it; the explicit reset
        // also covers the defensive break path in release builds.
        for &l in &comp_links {
            cnt[l] = 0;
        }
    }

    /// Recompute rates for the connected component(s) reachable from
    /// the dirty links; everything else keeps its allocation.
    fn recompute_dirty_components(&mut self) {
        // BFS over the link<->flow bipartite graph from the dirty links.
        let mut queue: Vec<usize> = Vec::with_capacity(self.dirty_links.len());
        for l in self.dirty_links.drain(..) {
            self.link_dirty[l] = false;
            if !self.scratch_link_seen[l] {
                self.scratch_link_seen[l] = true;
                queue.push(l);
            }
        }
        self.stamp += 1;
        let stamp = self.stamp;
        let mut comp_flows: Vec<FlowId> = Vec::new();
        let mut touched_links: Vec<usize> = queue.clone();
        while let Some(l) = queue.pop() {
            for &fid in &self.link_flows[l] {
                let f = self.flows.get_mut(fid).expect("member flow exists");
                if f.seen == stamp {
                    continue;
                }
                f.seen = stamp;
                comp_flows.push(fid);
                for p in &self.flows.get(fid).expect("member flow exists").path {
                    if !self.scratch_link_seen[p.0] {
                        self.scratch_link_seen[p.0] = true;
                        touched_links.push(p.0);
                        queue.push(p.0);
                    }
                }
            }
        }
        for l in touched_links {
            self.scratch_link_seen[l] = false;
        }
        comp_flows.sort_unstable();
        self.profile.dirty_recomputes += 1;
        self.profile.comp_flows_total += comp_flows.len() as u64;
        self.profile.comp_flows_max = self.profile.comp_flows_max.max(comp_flows.len() as u64);
        if !comp_flows.is_empty() {
            self.fill(&comp_flows);
        }
        self.rates_dirty = false;
    }

    /// Full re-fill over every flow (initial state, or the bench
    /// baseline knob).
    fn recompute_all(&mut self) {
        for l in self.dirty_links.drain(..) {
            self.link_dirty[l] = false;
        }
        self.profile.full_recomputes += 1;
        let ids: Vec<FlowId> = self.flows.iter().map(|(id, _)| id).collect();
        self.fill(&ids);
        self.rates_dirty = false;
    }

    fn ensure_rates(&mut self) {
        if !self.rates_dirty {
            return;
        }
        if self.full_recompute {
            self.recompute_all();
        } else {
            self.recompute_dirty_components();
        }
    }

    /// The pre-incremental global allocator, retained verbatim as the
    /// testing oracle: progressive filling over the entire flow set,
    /// computed from scratch without touching simulator state.
    /// rust/tests/props_netsim.rs asserts the incremental path agrees
    /// with this within 1e-9 on randomized topologies.
    pub fn oracle_rates(&self) -> Vec<(FlowId, f64)> {
        let nl = self.links.len();
        let mut remaining_cap: Vec<f64> = self.links.iter().map(|l| l.capacity).collect();
        let mut unfrozen_count: Vec<usize> = vec![0; nl];
        let entries: Vec<(FlowId, &Flow)> = self.flows.iter().collect();
        let mut rate = vec![0.0f64; entries.len()];
        let mut frozen = vec![false; entries.len()];
        for (_, f) in &entries {
            for l in &f.path {
                unfrozen_count[l.0] += 1;
            }
        }
        let mut unfrozen = entries.len();
        while unfrozen > 0 {
            let mut min_share = f64::INFINITY;
            for i in 0..nl {
                if unfrozen_count[i] > 0 {
                    min_share = min_share.min(remaining_cap[i] / unfrozen_count[i] as f64);
                }
            }
            let mut froze_capped = false;
            for (k, (_, f)) in entries.iter().enumerate() {
                if frozen[k] {
                    continue;
                }
                let effective_share = if f.path.is_empty() {
                    f64::INFINITY
                } else {
                    min_share
                };
                if f.rate_cap <= effective_share {
                    rate[k] = f.rate_cap;
                    for l in &f.path {
                        remaining_cap[l.0] = (remaining_cap[l.0] - f.rate_cap).max(0.0);
                        unfrozen_count[l.0] -= 1;
                    }
                    frozen[k] = true;
                    unfrozen -= 1;
                    froze_capped = true;
                }
            }
            if froze_capped {
                continue;
            }
            let mut froze_any = false;
            for i in 0..nl {
                if unfrozen_count[i] > 0
                    && (remaining_cap[i] / unfrozen_count[i] as f64) <= min_share * (1.0 + 1e-12)
                {
                    for (k, (_, f)) in entries.iter().enumerate() {
                        if !frozen[k] && f.path.iter().any(|l| l.0 == i) {
                            rate[k] = min_share;
                            for l in &f.path {
                                remaining_cap[l.0] = (remaining_cap[l.0] - min_share).max(0.0);
                                unfrozen_count[l.0] -= 1;
                            }
                            frozen[k] = true;
                            unfrozen -= 1;
                            froze_any = true;
                        }
                    }
                }
            }
            if !froze_any {
                break;
            }
        }
        entries
            .iter()
            .enumerate()
            .map(|(k, (id, _))| (*id, rate[k]))
            .collect()
    }

    /// Current allocated rate of a flow (bytes/s).
    pub fn flow_rate(&mut self, id: FlowId) -> f64 {
        self.ensure_rates();
        self.flows.get(id).expect("unknown flow").rate
    }

    pub fn flow_remaining(&self, id: FlowId) -> f64 {
        self.flows.get(id).expect("unknown flow").remaining
    }

    /// Abort an active flow (fault injection: a crashed receiver or
    /// sender). Returns the undelivered byte count so the caller can
    /// re-send it elsewhere.
    pub fn cancel_flow(&mut self, id: FlowId) -> f64 {
        self.try_cancel_flow(id).expect("cancel of unknown flow")
    }

    /// Like `cancel_flow`, but tolerates an id that is no longer
    /// active — e.g. a speculation loser that completed in the same
    /// `advance_to` batch as the winner cancelling it.  Returns the
    /// undelivered bytes, or `None` when the flow is gone.
    pub fn try_cancel_flow(&mut self, id: FlowId) -> Option<f64> {
        let f = self.flows.remove(id)?;
        self.detach(id, &f.path);
        Some(f.remaining)
    }

    /// (time, flow) of the earliest completion among active flows, given
    /// current rates — or None if no flows are active.  Memoized: the
    /// linear scan only reruns after the flow/link set changed.
    pub fn next_completion(&mut self) -> Option<(f64, FlowId)> {
        self.ensure_rates();
        if let Some(cached) = self.completion_cache {
            return cached;
        }
        let mut best: Option<(f64, FlowId)> = None;
        for (id, f) in self.flows.iter() {
            if f.rate <= 0.0 {
                continue;
            }
            let t = self.now + f.remaining / f.rate;
            if best.map(|(bt, _)| t < bt).unwrap_or(true) {
                best = Some((t, id));
            }
        }
        self.completion_cache = Some(best);
        best
    }

    /// Advance virtual time to `t`, progressing all flows at their
    /// current rates. Flows that hit zero are completed and returned.
    pub fn advance_to(&mut self, t: f64) -> Vec<FlowId> {
        assert!(t >= self.now - 1e-9, "time went backwards");
        self.ensure_rates();
        let dt = (t - self.now).max(0.0);
        self.now = t;
        let mut done = Vec::new();
        for (id, f) in self.flows.iter_mut() {
            let moved = (f.rate * dt).min(f.remaining);
            f.remaining -= moved;
            self.delivered_bytes += moved;
            if f.remaining <= 1e-6 {
                self.delivered_bytes += f.remaining;
                done.push(id);
            }
        }
        for id in &done {
            let f = self.flows.remove(*id).expect("completed flow exists");
            self.detach(*id, &f.path);
        }
        done
    }

    /// Drive the network alone until all flows finish; returns the
    /// completion time of the last one. (Helper for tests/benches that
    /// have no interleaved discrete events.)
    pub fn run_to_idle(&mut self) -> f64 {
        while let Some((t, _)) = self.next_completion() {
            self.advance_to(t);
        }
        self.now
    }

    /// Sum of allocated rates crossing a link (<= capacity; for tests).
    pub fn link_load(&mut self, l: LinkId) -> f64 {
        self.ensure_rates();
        self.flows
            .iter()
            .filter(|(_, f)| f.path.contains(&l))
            .map(|(_, f)| f.rate)
            .sum()
    }

    /// Allocated rate per link in one pass over the flow set — the
    /// trace sampler's per-tier utilization snapshot (calling
    /// [`NetSim::link_load`] per link would rescan every flow each
    /// time).  `out[l.0]` is the load crossing link `l`.
    pub fn link_loads(&mut self) -> Vec<f64> {
        self.ensure_rates();
        let mut out = vec![0.0; self.links.len()];
        for (_, f) in self.flows.iter() {
            for l in &f.path {
                out[l.0] += f.rate;
            }
        }
        out
    }

    /// Next flow id `start_flow` will assign.  Flow ids are a single
    /// monotone sequence, so `watermark .. flow_id_watermark()` names
    /// exactly the flows started since `watermark` was read — the
    /// trace layer's central flow-open detection.
    pub fn flow_id_watermark(&self) -> u64 {
        self.flows.next_id()
    }

    /// Snapshot of the self-profiling counters.
    pub fn profile(&self) -> NetProfile {
        self.profile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_flow_gets_min_of_cap_and_link() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1000.0, 250.0);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9);
        let f2 = net.start_flow(&[l], 1000.0, 30.0);
        assert!((net.flow_rate(f2) - 30.0).abs() < 1e-9);
        // f gets the rest
        assert!((net.flow_rate(f) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn profile_watermark_and_link_loads() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        assert_eq!(net.flow_id_watermark(), 0);
        let a = net.start_flow(&[l], 1000.0, 1e9);
        let b = net.start_flow(&[l], 1000.0, 1e9);
        assert_eq!(net.flow_id_watermark(), 2);
        assert_eq!((a.0, b.0), (0, 1));
        // One-pass per-link loads agree with the per-link scan.
        let loads = net.link_loads();
        assert!((loads[l.0] - net.link_load(l)).abs() < 1e-9);
        assert!((loads[l.0] - 100.0).abs() < 1e-9);
        // The incremental path ran and saw both flows in one component.
        let p = net.profile();
        assert!(p.dirty_recomputes >= 1);
        assert_eq!(p.full_recomputes, 0);
        assert_eq!(p.comp_flows_max, 2);
        assert!(p.comp_flows_mean() > 0.0);
        // The bench baseline knob routes through the full recompute.
        net.set_full_recompute(true);
        net.start_flow(&[l], 1000.0, 1e9);
        net.flow_rate(a);
        assert!(net.profile().full_recomputes >= 1);
        assert_eq!(net.flow_id_watermark(), 3);
    }

    #[test]
    fn link_flow_counts_track_membership() {
        let mut net = NetSim::new();
        let a = net.add_link(100.0);
        let b = net.add_link(100.0);
        assert_eq!(net.link_flow_counts(), vec![0, 0]);
        let f1 = net.start_flow(&[a, b], 1000.0, 1e9);
        let _f2 = net.start_flow(&[a], 1000.0, 1e9);
        assert_eq!(net.link_flow_counts(), vec![2, 1]);
        // Cancellation prunes membership immediately...
        net.cancel_flow(f1);
        assert_eq!(net.link_flow_counts(), vec![1, 0]);
        // ...and so does completion.
        net.run_to_idle();
        assert_eq!(net.link_flow_counts(), vec![0, 0]);
    }

    #[test]
    fn equal_flows_share_equally() {
        let mut net = NetSim::new();
        let l = net.add_link(90.0);
        let fs: Vec<FlowId> = (0..3).map(|_| net.start_flow(&[l], 900.0, 1e9)).collect();
        for f in &fs {
            assert!((net.flow_rate(*f) - 30.0).abs() < 1e-9);
        }
        assert!(net.link_load(l) <= 90.0 + 1e-9);
    }

    #[test]
    fn capped_flow_releases_bandwidth() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let slow = net.start_flow(&[l], 1e6, 10.0);
        let fast = net.start_flow(&[l], 1e6, 1e9);
        assert!((net.flow_rate(slow) - 10.0).abs() < 1e-9);
        assert!((net.flow_rate(fast) - 90.0).abs() < 1e-9);
    }

    #[test]
    fn multi_link_path_bottlenecked_by_narrowest() {
        let mut net = NetSim::new();
        let wide = net.add_link(1000.0);
        let narrow = net.add_link(50.0);
        let f = net.start_flow(&[wide, narrow], 500.0, 1e9);
        assert!((net.flow_rate(f) - 50.0).abs() < 1e-9);
    }

    #[test]
    fn completion_times_and_rate_rebalance() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let _a = net.start_flow(&[l], 100.0, 1e9); // at 50 B/s -> 2 s
        let b = net.start_flow(&[l], 300.0, 1e9);
        let (t1, _) = net.next_completion().unwrap();
        assert!((t1 - 2.0).abs() < 1e-9);
        let done = net.advance_to(t1);
        assert_eq!(done.len(), 1);
        // b then speeds up to 100 B/s with 200 bytes left -> +2 s
        let (t2, id2) = net.next_completion().unwrap();
        assert_eq!(id2, b);
        assert!((t2 - 4.0).abs() < 1e-9);
        net.advance_to(t2);
        assert_eq!(net.active_flows(), 0);
        assert!((net.delivered_bytes - 400.0).abs() < 1e-6);
    }

    #[test]
    fn pathless_flow_runs_at_cap() {
        let mut net = NetSim::new();
        let f = net.start_flow(&[], 100.0, 25.0);
        assert!((net.flow_rate(f) - 25.0).abs() < 1e-12);
        assert!((net.run_to_idle() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn cross_traffic_max_min() {
        // Two links A, B. Flow1 uses A+B, flow2 uses A, flow3 uses B.
        // cap(A)=100, cap(B)=60: flow1 and flow3 split B at 30 each;
        // flow2 then gets 70 on A.
        let mut net = NetSim::new();
        let a = net.add_link(100.0);
        let b = net.add_link(60.0);
        let f1 = net.start_flow(&[a, b], 1e6, 1e9);
        let f2 = net.start_flow(&[a], 1e6, 1e9);
        let f3 = net.start_flow(&[b], 1e6, 1e9);
        assert!((net.flow_rate(f1) - 30.0).abs() < 1e-9);
        assert!((net.flow_rate(f3) - 30.0).abs() < 1e-9);
        assert!((net.flow_rate(f2) - 70.0).abs() < 1e-9);
    }

    #[test]
    fn link_degradation_reroutes_rates() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1e6, 1e9);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9);
        net.set_link_capacity(l, 25.0);
        assert!((net.flow_rate(f) - 25.0).abs() < 1e-9, "degraded");
        net.set_link_capacity(l, 100.0);
        assert!((net.flow_rate(f) - 100.0).abs() < 1e-9, "repaired");
    }

    #[test]
    fn cancel_flow_returns_undelivered_bytes() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let a = net.start_flow(&[l], 1000.0, 1e9);
        let b = net.start_flow(&[l], 1000.0, 1e9);
        net.advance_to(2.0); // each moved 100 bytes at 50 B/s
        let left = net.cancel_flow(a);
        assert!((left - 900.0).abs() < 1e-6);
        // survivor reclaims the full link
        assert!((net.flow_rate(b) - 100.0).abs() < 1e-9);
        assert_eq!(net.active_flows(), 1);
    }

    #[test]
    fn try_cancel_tolerates_finished_flows() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let a = net.start_flow(&[l], 100.0, 1e9);
        let b = net.start_flow(&[l], 1000.0, 1e9);
        assert!(net.try_cancel_flow(a).is_some(), "active flow cancels");
        assert!(net.try_cancel_flow(a).is_none(), "second cancel is a no-op");
        net.run_to_idle();
        assert!(net.try_cancel_flow(b).is_none(), "completed flow is gone");
    }

    #[test]
    fn next_completion_memo_survives_idle_advances_and_invalidates() {
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        let f = net.start_flow(&[l], 1000.0, 1e9); // completes at t=10
        let first = net.next_completion().unwrap();
        assert_eq!(first.1, f);
        // Advancing without completing anything must not change the
        // answer (this is the memoized path).
        net.advance_to(3.0);
        assert_eq!(net.next_completion().unwrap(), first);
        // A new flow invalidates: it shares the link, finishes first.
        let short = net.start_flow(&[l], 10.0, 1e9);
        let (t, id) = net.next_completion().unwrap();
        assert_eq!(id, short);
        assert!((t - 3.2).abs() < 1e-9, "50 B/s share, 10 bytes: {t}");
        // Capacity changes invalidate too.
        net.set_link_capacity(l, 50.0);
        let (t, _) = net.next_completion().unwrap();
        assert!((t - 3.4).abs() < 1e-9, "25 B/s share after degrade: {t}");
    }

    #[test]
    fn run_to_idle_conserves_bytes() {
        let mut net = NetSim::new();
        let l = net.add_link(10.0);
        for i in 1..=5 {
            net.start_flow(&[l], 10.0 * i as f64, 1e9);
        }
        net.run_to_idle();
        assert!((net.delivered_bytes - 150.0).abs() < 1e-3);
        assert_eq!(net.active_flows(), 0);
    }

    #[test]
    fn incremental_update_leaves_other_components_alone() {
        // Two disjoint components: changing one must not disturb the
        // other's rates, and both must match the global oracle.
        let mut net = NetSim::new();
        let a = net.add_link(100.0);
        let b = net.add_link(80.0);
        let f1 = net.start_flow(&[a], 1e6, 1e9);
        let f2 = net.start_flow(&[a], 1e6, 1e9);
        let g1 = net.start_flow(&[b], 1e6, 1e9);
        assert!((net.flow_rate(f1) - 50.0).abs() < 1e-9);
        assert!((net.flow_rate(g1) - 80.0).abs() < 1e-9);
        // Perturb only component A.
        net.cancel_flow(f2);
        assert!((net.flow_rate(f1) - 100.0).abs() < 1e-9);
        assert!((net.flow_rate(g1) - 80.0).abs() < 1e-9, "B untouched");
        for (id, want) in net.oracle_rates() {
            assert!(
                (net.flow_rate(id) - want).abs() < 1e-9,
                "flow {id:?}: incremental vs oracle"
            );
        }
    }

    #[test]
    fn full_recompute_knob_matches_incremental() {
        let build = |full: bool| {
            let mut net = NetSim::new();
            net.set_full_recompute(full);
            let a = net.add_link(100.0);
            let b = net.add_link(60.0);
            let c = net.add_link(40.0);
            net.start_flow(&[a, b], 1e5, 1e9);
            net.start_flow(&[a], 1e5, 35.0);
            net.start_flow(&[b], 1e5, 1e9);
            net.start_flow(&[c], 1e5, 1e9);
            net.advance_to(net.next_completion().unwrap().0);
            net.set_link_capacity(a, 55.0);
            net.run_to_idle();
            (net.now(), net.delivered_bytes)
        };
        let (t_inc, d_inc) = build(false);
        let (t_full, d_full) = build(true);
        assert!((t_inc - t_full).abs() < 1e-9, "{t_inc} vs {t_full}");
        assert!((d_inc - d_full).abs() < 1e-6);
    }

    #[test]
    fn flow_table_window_compacts_under_churn() {
        // Sustained churn must not grow memory: the id window tracks
        // live flows because retired slots compact off the front.
        let mut net = NetSim::new();
        let l = net.add_link(1e6);
        for _ in 0..1000 {
            net.start_flow(&[l], 10.0, 1e9);
            net.run_to_idle();
            assert_eq!(net.active_flows(), 0);
        }
        assert!(net.flows.slots.len() <= 1, "window: {}", net.flows.slots.len());
        let f = net.start_flow(&[l], 10.0, 1e9);
        assert_eq!(f, FlowId(1000), "ids stay monotone across compaction");
    }
}

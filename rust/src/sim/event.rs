//! Deterministic discrete-event queue.
//!
//! Virtual time is `f64` seconds.  Ties are broken by insertion sequence
//! number so a given seed always replays the identical timeline — a core
//! test invariant (see rust/tests/sim_determinism.rs).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event.
struct Entry<E> {
    time: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; earlier time first, then lower seq.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with stable FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Pre-size the heap for a known event population (scenario engine:
    /// one in-flight event per SPE plus the fault plan). Avoids
    /// re-allocation churn in the hot loop at 128+ node scale.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute time `time` (must not be in the past).
    pub fn push_at(&mut self, time: f64, ev: E) {
        debug_assert!(
            time >= self.now,
            "event scheduled in the past: {time} < {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, ev });
    }

    /// Schedule `ev` after a delay relative to `now()`.
    pub fn push_after(&mut self, delay: f64, ev: E) {
        debug_assert!(delay >= 0.0);
        self.push_at(self.now + delay, ev);
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.ev)
        })
    }

    /// Drain every event sharing the earliest timestamp into `out`
    /// (FIFO order preserved) and return that timestamp. Big scenarios
    /// finish whole waves of segments at identical virtual times;
    /// batching the wave into one heap drain lets the caller handle it
    /// with a single scheduler pass instead of per-event bookkeeping.
    pub fn pop_simultaneous(&mut self, out: &mut Vec<E>) -> Option<f64> {
        let (t, first) = self.pop()?;
        out.push(first);
        while self.peek_time() == Some(t) {
            out.push(self.pop().expect("peeked event exists").1);
        }
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_with_pops() {
        let mut q = EventQueue::new();
        q.push_at(2.5, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 2.5);
        q.push_after(1.5, ());
        assert_eq!(q.pop().unwrap().0, 4.0);
    }

    #[test]
    fn pop_simultaneous_batches_ties() {
        let mut q = EventQueue::with_capacity(8);
        q.push_at(1.0, "early");
        for i in 0..3 {
            q.push_at(2.0, if i == 0 { "a" } else if i == 1 { "b" } else { "c" });
        }
        q.push_at(3.0, "late");
        let mut batch = Vec::new();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(1.0));
        assert_eq!(batch, vec!["early"]);
        batch.clear();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(2.0));
        assert_eq!(batch, vec!["a", "b", "c"], "FIFO within the wave");
        batch.clear();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(3.0));
        assert_eq!(q.pop_simultaneous(&mut batch), None);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push_at(5.0, ());
        q.pop();
        q.push_at(1.0, ());
    }
}

//! Deterministic discrete-event queue.
//!
//! Virtual time is `f64` seconds.  Ties are broken by insertion sequence
//! number so a given seed always replays the identical timeline — a core
//! test invariant (see rust/tests/sim_determinism.rs).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One scheduled event.
struct Entry<E> {
    time: f64,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<E> Eq for Entry<E> {}

impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reverse for a min-heap; earlier time first, then lower seq.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN event time")
            .then(other.seq.cmp(&self.seq))
    }
}

impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue with stable FIFO tie-breaking.
pub struct EventQueue<E> {
    heap: BinaryHeap<Entry<E>>,
    seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
            now: 0.0,
        }
    }

    /// Pre-size the heap for a known event population (scenario engine:
    /// one in-flight event per SPE plus the fault plan). Avoids
    /// re-allocation churn in the hot loop at 128+ node scale.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            heap: BinaryHeap::with_capacity(cap),
            seq: 0,
            now: 0.0,
        }
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `ev` at absolute time `time` (must not be in the past).
    pub fn push_at(&mut self, time: f64, ev: E) {
        debug_assert!(
            time >= self.now,
            "event scheduled in the past: {time} < {}",
            self.now
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { time, seq, ev });
    }

    /// Schedule `ev` after a delay relative to `now()`.
    pub fn push_after(&mut self, delay: f64, ev: E) {
        debug_assert!(delay >= 0.0);
        self.push_at(self.now + delay, ev);
    }

    /// Time of the next event without popping it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Pop the next event, advancing virtual time.
    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|e| {
            self.now = e.time;
            (e.time, e.ev)
        })
    }

    /// Drain every event sharing the earliest timestamp into `out`
    /// (FIFO order preserved) and return that timestamp. Big scenarios
    /// finish whole waves of segments at identical virtual times;
    /// batching the wave into one heap drain lets the caller handle it
    /// with a single scheduler pass instead of per-event bookkeeping.
    pub fn pop_simultaneous(&mut self, out: &mut Vec<E>) -> Option<f64> {
        let (t, first) = self.pop()?;
        out.push(first);
        while self.peek_time() == Some(t) {
            out.push(self.pop().expect("peeked event exists").1);
        }
        Some(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time() {
        let mut q = EventQueue::new();
        q.push_at(3.0, "c");
        q.push_at(1.0, "a");
        q.push_at(2.0, "b");
        assert_eq!(q.peek_time(), Some(1.0));
        let order: Vec<&str> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn fifo_on_ties() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push_at(5.0, i);
        }
        let order: Vec<i32> = std::iter::from_fn(|| q.pop().map(|(_, e)| e)).collect();
        assert_eq!(order, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn now_advances_with_pops() {
        let mut q = EventQueue::new();
        q.push_at(2.5, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 2.5);
        q.push_after(1.5, ());
        assert_eq!(q.pop().unwrap().0, 4.0);
    }

    #[test]
    fn pop_simultaneous_batches_ties() {
        let mut q = EventQueue::with_capacity(8);
        q.push_at(1.0, "early");
        for i in 0..3 {
            q.push_at(2.0, if i == 0 { "a" } else if i == 1 { "b" } else { "c" });
        }
        q.push_at(3.0, "late");
        let mut batch = Vec::new();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(1.0));
        assert_eq!(batch, vec!["early"]);
        batch.clear();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(2.0));
        assert_eq!(batch, vec!["a", "b", "c"], "FIFO within the wave");
        batch.clear();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(3.0));
        assert_eq!(q.pop_simultaneous(&mut batch), None);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn rejects_past_events() {
        let mut q = EventQueue::new();
        q.push_at(5.0, ());
        q.pop();
        q.push_at(1.0, ());
    }

    #[test]
    fn tie_break_is_deterministic_across_replays() {
        // Same pushes, same drain order — even when every timestamp is
        // identical and the heap's internal layout is all that differs.
        let run = || {
            let mut q = EventQueue::with_capacity(64);
            for i in 0..20 {
                q.push_at(7.0, i);
            }
            for i in 20..40 {
                q.push_at(3.0, i);
            }
            let mut order = Vec::new();
            let mut batch = Vec::new();
            while let Some(t) = q.pop_simultaneous(&mut batch) {
                order.push((t, batch.clone()));
                batch.clear();
            }
            order
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "replay determinism");
        assert_eq!(a.len(), 2, "two waves");
        assert_eq!(a[0].1, (20..40).collect::<Vec<_>>(), "FIFO within t=3 wave");
        assert_eq!(a[1].1, (0..20).collect::<Vec<_>>(), "FIFO within t=7 wave");
    }

    #[test]
    fn fifo_holds_for_events_pushed_mid_drain() {
        // Events scheduled *during* a wave for the same instant join a
        // later wave (pop_simultaneous snapshots the earliest time),
        // still in push order.
        let mut q = EventQueue::new();
        q.push_at(1.0, "a");
        q.push_at(1.0, "b");
        let mut batch = Vec::new();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(1.0));
        assert_eq!(batch, vec!["a", "b"]);
        q.push_at(1.0, "late1"); // same instant, scheduled by a handler
        q.push_at(1.0, "late2");
        batch.clear();
        assert_eq!(q.pop_simultaneous(&mut batch), Some(1.0));
        assert_eq!(batch, vec!["late1", "late2"], "handler pushes stay FIFO");
    }

    #[test]
    fn interleaves_with_netsim_completion_memo() {
        // The engine-core loop pattern: next = min(queue, net), and the
        // NetSim completion memo must stay coherent when a drained
        // event changes link capacity mid-wave (the PR-2 memo's latent
        // staleness class).
        use crate::sim::netsim::NetSim;
        let mut net = NetSim::new();
        let l = net.add_link(100.0);
        net.start_flow(&[l], 1000.0, 1e9); // completes at t=10 at full rate
        let mut q = EventQueue::new();
        q.push_at(4.0, "degrade");
        q.push_at(4.0, "observer");

        // First engine step: the queue wins (4.0 < 10.0).
        let tq = q.peek_time().unwrap();
        let tn = net.next_completion().unwrap().0;
        assert!((tn - 10.0).abs() < 1e-9);
        let next = tq.min(tn);
        assert_eq!(next, 4.0);
        net.advance_to(next); // idle advance: memo must survive
        assert_eq!(net.next_completion().unwrap().0, tn, "memoized answer");
        let mut batch = Vec::new();
        q.pop_simultaneous(&mut batch);
        assert_eq!(batch, vec!["degrade", "observer"]);
        for ev in batch.drain(..) {
            if ev == "degrade" {
                net.set_link_capacity(l, 30.0);
            } else {
                // A handler later in the same batch reads the memo: it
                // must already see the degraded rate, not a stale time.
                let (t, _) = net.next_completion().unwrap();
                assert!(
                    (t - (4.0 + 600.0 / 30.0)).abs() < 1e-9,
                    "completion time reflects mid-drain capacity change: {t}"
                );
            }
        }
        // Second engine step: the network is all that's left.
        assert_eq!(q.peek_time(), None);
        let (t, _) = net.next_completion().unwrap();
        let done = net.advance_to(t);
        assert_eq!(done.len(), 1);
        assert!((net.delivered_bytes - 1000.0).abs() < 1e-6);
    }
}

//! Discrete-event simulation substrate.
//!
//! The paper's testbeds (a 6-server 3-site 10 Gb/s WAN and an 8-server
//! rack) are simulated at flow/op granularity: `netsim` shares link
//! bandwidth max-min fairly among flows capped by their transport
//! protocol model, `disk` serializes spindle operations, `cpu` schedules
//! core time, and `event` provides the deterministic virtual clock the
//! job simulators (`sphere::simjob`, `hadoop::simjob`) drive.

pub mod cpu;
pub mod disk;
pub mod event;
pub mod netsim;

pub use cpu::CpuPool;
pub use disk::{DiskModel, DiskOp};
pub use event::EventQueue;
pub use netsim::{FlowId, LinkId, NetSim};

//! Disk model: a per-node serialized queue with distinct sequential
//! read/write throughputs and a per-operation seek cost.
//!
//! The Terasort tables are disk-dominated (the paper sorts 10 GB/node on
//! 2008-era SATA arrays), so the model keeps the two properties that
//! matter: operations on one disk serialize, and random access pays a
//! seek.  Concurrent streams on a node are modelled by interleaving ops
//! through the queue (fair, in issue order).

#[derive(Clone, Debug)]
pub struct DiskModel {
    /// Sequential read bandwidth, bytes/s.
    pub read_bps: f64,
    /// Sequential write bandwidth, bytes/s.
    pub write_bps: f64,
    /// Per-operation positioning cost, seconds.
    pub seek_secs: f64,
    /// Time at which the disk becomes free.
    busy_until: f64,
    /// Accounting.
    pub bytes_read: f64,
    pub bytes_written: f64,
    pub busy_secs: f64,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DiskOp {
    Read,
    Write,
}

impl DiskModel {
    pub fn new(read_bps: f64, write_bps: f64, seek_secs: f64) -> Self {
        assert!(read_bps > 0.0 && write_bps > 0.0 && seek_secs >= 0.0);
        Self {
            read_bps,
            write_bps,
            seek_secs,
            busy_until: 0.0,
            bytes_read: 0.0,
            bytes_written: 0.0,
            busy_secs: 0.0,
        }
    }

    /// Issue an operation at time `now`; returns its completion time.
    /// Ops serialize: service begins at max(now, busy_until).
    pub fn submit(&mut self, now: f64, op: DiskOp, bytes: f64) -> f64 {
        assert!(bytes >= 0.0);
        let bps = match op {
            DiskOp::Read => {
                self.bytes_read += bytes;
                self.read_bps
            }
            DiskOp::Write => {
                self.bytes_written += bytes;
                self.write_bps
            }
        };
        let start = now.max(self.busy_until);
        let service = self.seek_secs + bytes / bps;
        self.busy_until = start + service;
        self.busy_secs += service;
        self.busy_until
    }

    /// Effective streaming rate for a long-lived source feeding the
    /// network (used as the flow rate cap of a disk-bound sender that is
    /// also sharing the spindle with `concurrent` other streams).
    pub fn stream_rate(&self, op: DiskOp, concurrent: usize) -> f64 {
        let base = match op {
            DiskOp::Read => self.read_bps,
            DiskOp::Write => self.write_bps,
        };
        base / concurrent.max(1) as f64
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    /// Utilization over an observation window ending at `now`.
    pub fn utilization(&self, now: f64) -> f64 {
        if now <= 0.0 {
            0.0
        } else {
            (self.busy_secs / now).min(1.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_serialize() {
        let mut d = DiskModel::new(100.0, 50.0, 0.0);
        let t1 = d.submit(0.0, DiskOp::Read, 200.0); // 2 s
        assert!((t1 - 2.0).abs() < 1e-12);
        // issued "concurrently" at t=0, starts after the first finishes
        let t2 = d.submit(0.0, DiskOp::Write, 100.0); // 2 s service
        assert!((t2 - 4.0).abs() < 1e-12);
        // issued later than free time: starts immediately
        let t3 = d.submit(10.0, DiskOp::Read, 100.0);
        assert!((t3 - 11.0).abs() < 1e-12);
    }

    #[test]
    fn seek_cost_applies_per_op() {
        let mut d = DiskModel::new(100.0, 100.0, 0.5);
        let t = d.submit(0.0, DiskOp::Read, 100.0);
        assert!((t - 1.5).abs() < 1e-12);
    }

    #[test]
    fn accounting() {
        let mut d = DiskModel::new(10.0, 10.0, 0.0);
        d.submit(0.0, DiskOp::Read, 30.0);
        d.submit(0.0, DiskOp::Write, 20.0);
        assert_eq!(d.bytes_read, 30.0);
        assert_eq!(d.bytes_written, 20.0);
        assert!((d.utilization(5.0) - 1.0).abs() < 1e-12);
        assert!(d.utilization(0.0) == 0.0);
    }

    #[test]
    fn stream_rate_divides() {
        let d = DiskModel::new(120.0, 60.0, 0.0);
        assert_eq!(d.stream_rate(DiskOp::Read, 0), 120.0);
        assert_eq!(d.stream_rate(DiskOp::Read, 3), 40.0);
        assert_eq!(d.stream_rate(DiskOp::Write, 2), 30.0);
    }
}

//! Figures 5 and 6 reproduction: the delta_j cluster-movement series.
//!
//! Fig 5: 10-minute windows — high-variance, "quite choppy".
//! Fig 6: 1-day windows — smooth baseline with emergent-cluster spikes
//! on the anomalous days (three in the paper; we plant three regime
//! shifts and verify the detector flags exactly those windows).
//!
//!     cargo bench --bench bench_figures

use sector_sphere::mining::emergent::{analyze_windows, emergent_windows};
use sector_sphere::mining::features::{extract_features, FeatureVector};
use sector_sphere::mining::pcap::{Regime, TraceGen};
use sector_sphere::util::hist::ascii_plot;
use sector_sphere::util::stats::Summary;

/// Generate a delta series: `windows` windows, `per_window` packets per
/// source; `pool` sources re-drawn per window model churn; anomalies at
/// the given windows.
fn delta_series(
    windows: u64,
    sources: usize,
    packets: usize,
    churn: bool,
    anomalies: &[(u64, Regime)],
    seed: u64,
) -> Vec<f64> {
    let mut feats: Vec<Vec<FeatureVector>> = Vec::new();
    for w in 0..windows {
        // churn: a different subset of sources active each short window
        // (this is what makes the 10-minute series choppy); long windows
        // aggregate everything and are stable.
        let mut gen = TraceGen::new(1, sources, seed + if churn { w * 131 } else { 0 });
        let anom: Vec<(usize, Regime)> = anomalies
            .iter()
            .filter(|(aw, _)| *aw == w)
            .flat_map(|(_, r)| (0..sources / 8).map(move |s| (s * 3, *r)))
            .collect();
        let pkts = gen.window(w, packets, &anom);
        feats.push(extract_features(&pkts, w));
    }
    analyze_windows(&feats, 5, seed, None).expect("analysis").deltas
}

fn main() {
    // ---- Fig 5: 10-minute windows, choppy ----
    let fig5 = delta_series(36, 40, 30, true, &[], 7);
    println!("\n=== Figure 5 — delta_j, 10-minute windows (choppy) ===");
    print!("{}", ascii_plot(&fig5, 64, 9));
    let s5 = Summary::of(&fig5).unwrap();
    println!(
        "n={} mean={:.3} std={:.3} cv={:.2}",
        s5.n,
        s5.mean,
        s5.std_dev,
        s5.std_dev / s5.mean
    );

    // ---- Fig 6: 1-day windows, smooth + 3 emergent days ----
    let planted = [(9u64, Regime::Scan), (17, Regime::Exfil), (27, Regime::Scan)];
    let fig6 = delta_series(36, 40, 200, false, &planted, 11);
    println!("\n=== Figure 6 — delta_j, 1-day windows (3 emergent days planted) ===");
    print!("{}", ascii_plot(&fig6, 64, 9));
    let flagged = emergent_windows(&fig6, 3, 3.0);
    println!("emergent windows flagged: {flagged:?} (planted at 9, 17, 27)");

    // Reproduction checks: the paper's qualitative contrast.
    let baseline6: Vec<f64> = fig6
        .iter()
        .enumerate()
        .filter(|(j, _)| {
            // deltas adjacent to planted windows are spikes
            !planted
                .iter()
                .any(|(w, _)| *j == *w as usize - 1 || *j == *w as usize)
        })
        .map(|(_, &d)| d)
        .collect();
    let s6 = Summary::of(&baseline6).unwrap();
    let cv5 = s5.std_dev / s5.mean;
    let cv6 = s6.std_dev / s6.mean;
    println!("\nchoppiness (coefficient of variation): fig5 {cv5:.2} vs fig6 baseline {cv6:.2}");
    assert!(
        cv5 > 1.5 * cv6,
        "10-minute windows must be choppier than 1-day baseline ({cv5:.2} vs {cv6:.2})"
    );
    for (w, _) in planted {
        assert!(
            flagged.contains(&(w as usize)),
            "planted emergent day {w} not flagged (flagged {flagged:?})"
        );
    }
    let spurious: Vec<&usize> = flagged
        .iter()
        .filter(|&&f| !planted.iter().any(|(w, _)| f == *w as usize || f == *w as usize + 1))
        .collect();
    println!("spurious flags: {spurious:?}");
    println!("\nfigures OK: choppy short windows, smooth long windows, 3 emergent days detected");
}

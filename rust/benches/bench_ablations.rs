//! Ablations over the design choices DESIGN.md §5 calls out:
//!   1. UDT vs TCP data transport (the §5 networking-layer claim);
//!   2. file vs block data granularity (Sector vs HDFS contrast, §2);
//!   3. locality scheduling on/off (Sphere rule 2);
//!   4. connection caching on/off (§4);
//!   5. Hadoop 64 MB vs 128 MB blocks (the paper's own tuning note).
//!
//!     cargo bench --bench bench_ablations

use sector_sphere::bench::Report;
use sector_sphere::config::{SimConfig, TransportKind};
use sector_sphere::hadoop::simulate_hadoop_terasort;
use sector_sphere::mining::terasort::{generate_records, record_index, TeraPartitionOp};
use sector_sphere::sector::SectorCloud;
use sector_sphere::sphere::simjob::{simulate_sphere_terasplit, simulate_sphere_terasort};
use sector_sphere::sphere::{run_job, FaultPlan, JobSpec, Stream};
use sector_sphere::topology::Testbed;
use sector_sphere::transport::{TransportModels, ConnectionCache};
use sector_sphere::util::bytes::{GB, MB};

fn main() {
    let bytes = 10.0 * GB as f64;
    let wan = Testbed::wan_testbed(6);

    // ---- 1. transport swap on the WAN ----
    let mut cfg = SimConfig::wan_default();
    let udt_sort = simulate_sphere_terasort(&wan, &cfg, bytes).terasort_secs;
    let udt_split = simulate_sphere_terasplit(&wan, &cfg, bytes);
    cfg.sphere_transport = TransportKind::Tcp;
    let tcp_sort = simulate_sphere_terasort(&wan, &cfg, bytes).terasort_secs;
    let tcp_split = simulate_sphere_terasplit(&wan, &cfg, bytes);
    let mut r = Report::new(
        "Ablation 1 — Sphere transport on the 6-node WAN (seconds)",
        &["terasort".into(), "terasplit".into()],
    );
    r.row("UDT (paper design)", vec![udt_sort, udt_split]);
    r.row("TCP (swapped)", vec![tcp_sort, tcp_split]);
    r.note("terasort is disk-bound; terasplit streams the WAN and shows the UDT win directly");
    println!("{}", r.render());
    assert!(tcp_split > 2.0 * udt_split);

    // ---- 2. granularity: segments per TB, file vs block model ----
    let tb = 1.0e12;
    let sector_chunks = tb / (15.6e9); // the paper's ~64 files per TB
    let hdfs_blocks_128 = tb / (128.0 * MB as f64);
    let mut r = Report::new(
        "Ablation 2 — data granularity per TB (the paper's §2 contrast)",
        &["chunks".into()],
    );
    r.row("Sector files (~15.6 GB each)", vec![sector_chunks.round()]);
    r.row("HDFS 128 MB blocks", vec![hdfs_blocks_128.round()]);
    r.note("64 vs 8192 units of placement/lookup/scheduling state per TB");
    println!("{}", r.render());

    // ---- 3. locality scheduling on/off (real cluster, real bytes) ----
    let mut rows = Vec::new();
    for locality in [true, false] {
        let cloud = SectorCloud::builder().nodes(8).seed(13).build().unwrap();
        let ip = "10.0.0.60".parse().unwrap();
        let mut names = Vec::new();
        for node in 0..8u32 {
            let data = generate_records(4000, node as u64);
            let idx = record_index(&data);
            let name = format!("in{node}.dat");
            cloud.upload(ip, &name, &data, Some(&idx), Some(node)).unwrap();
            names.push(name);
        }
        let stream = Stream::from_cloud(&cloud, &names).unwrap();
        let res = run_job(
            &cloud,
            &TeraPartitionOp { buckets: 32 },
            &stream,
            &JobSpec {
                output_name: format!("loc{locality}"),
                seg_min_bytes: 50_000,
                seg_max_bytes: 100_000,
                locality,
                ..JobSpec::default()
            },
            &FaultPlan::default(),
        )
        .unwrap();
        rows.push((locality, res.locality_fraction));
    }
    let mut r = Report::new(
        "Ablation 3 — Sphere locality scheduling (8-node real cluster)",
        &["local read fraction".into()],
    );
    r.row("locality + delay scheduling ON", vec![rows[0].1]);
    r.row("locality OFF (FIFO)", vec![rows[1].1]);
    println!("{}", r.render());
    assert!(rows[0].1 > rows[1].1, "locality scheduling must help");

    // ---- 4. connection cache on/off ----
    let models = TransportModels::default();
    let transfers = 200;
    let rtt = 0.055;
    for enabled in [true, false] {
        let mut cache = ConnectionCache::new(64, 600.0);
        cache.enabled = enabled;
        let mut setup_total = 0.0;
        for i in 0..transfers {
            let hit = cache.acquire(i as f64, 0, 1 + (i % 3));
            setup_total += models.setup_secs_for(TransportKind::Udt, rtt, hit);
        }
        println!(
            "Ablation 4 — connection cache {}: {:.1}s setup over {transfers} transfers (hit rate {:.0}%)",
            if enabled { "ON " } else { "OFF" },
            setup_total,
            cache.hit_rate() * 100.0
        );
    }

    // ---- 5. Hadoop block size (the paper bumped 64 -> 128 MB) ----
    let mut cfg64 = SimConfig::wan_default();
    cfg64.hadoop.block_bytes = 64 * MB;
    let t64 = simulate_hadoop_terasort(&wan, &cfg64, bytes).terasort_secs;
    let cfg128 = SimConfig::wan_default();
    let t128 = simulate_hadoop_terasort(&wan, &cfg128, bytes).terasort_secs;
    let mut r = Report::new(
        "Ablation 5 — Hadoop block size, WAN terasort (seconds)",
        &["terasort".into()],
    );
    r.row("64 MB blocks (default)", vec![t64]);
    r.row("128 MB blocks (paper's tuning)", vec![t128]);
    r.note("the paper: 'We increased this to 128 MB ... which improved the Hadoop results'");
    println!("{}", r.render());
    assert!(t128 < t64, "bigger blocks must help (fewer task startups)");

    println!("ablations OK");
}

//! Table 1 reproduction: WAN Terasort + Terasplit, Sphere vs Hadoop,
//! 10 GB/node over 1..6 nodes across up to 3 sites.
//!
//!     cargo bench --bench bench_table1

use sector_sphere::bench::Report;
use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::simulate_hadoop_row;
use sector_sphere::sphere::simjob::simulate_sphere_row;
use sector_sphere::topology::Testbed;
use sector_sphere::util::bytes::GB;

// Paper Table 1 rows (seconds), nodes 1..6.
const PAPER_HADOOP_SORT: [f64; 6] = [2312.0, 2401.0, 2623.0, 3228.0, 3358.0, 3532.0];
const PAPER_SPHERE_SORT: [f64; 6] = [905.0, 980.0, 1106.0, 1260.0, 1401.0, 1450.0];
const PAPER_HADOOP_SPLIT: [f64; 6] = [460.0, 623.0, 860.0, 1038.0, 1272.0, 1501.0];
const PAPER_SPHERE_SPLIT: [f64; 6] = [110.0, 320.0, 422.0, 571.0, 701.0, 923.0];

fn main() {
    let bytes = 10.0 * GB as f64;
    let cfg = SimConfig::wan_default();
    let cols: Vec<String> = (1..=6).map(|n| format!("n={n}")).collect();

    let mut sphere_sort = Vec::new();
    let mut hadoop_sort = Vec::new();
    let mut sphere_split = Vec::new();
    let mut hadoop_split = Vec::new();
    for n in 1..=6 {
        let t = Testbed::wan_testbed(n);
        let s = simulate_sphere_row(&t, &cfg, bytes);
        let h = simulate_hadoop_row(&t, &cfg, bytes);
        sphere_sort.push(s.terasort_secs);
        sphere_split.push(s.terasplit_secs);
        hadoop_sort.push(h.terasort_secs);
        hadoop_split.push(h.terasplit_secs);
    }
    let total =
        |a: &[f64], b: &[f64]| -> Vec<f64> { a.iter().zip(b).map(|(x, y)| x + y).collect() };
    let ratio =
        |a: &[f64], b: &[f64]| -> Vec<f64> { a.iter().zip(b).map(|(x, y)| x / y).collect() };

    let mut r = Report::new(
        "Table 1 — WAN Terasort/Terasplit (10 GB/node; 2x Chicago, 2x Pasadena, 2x Greenbelt)",
        &cols,
    );
    r.row("Hadoop Terasort (paper)", PAPER_HADOOP_SORT.to_vec());
    r.row("Hadoop Terasort (sim)", hadoop_sort.clone());
    r.row("Sphere Terasort (paper)", PAPER_SPHERE_SORT.to_vec());
    r.row("Sphere Terasort (sim)", sphere_sort.clone());
    r.row("Hadoop Terasplit (paper)", PAPER_HADOOP_SPLIT.to_vec());
    r.row("Hadoop Terasplit (sim)", hadoop_split.clone());
    r.row("Sphere Terasplit (paper)", PAPER_SPHERE_SPLIT.to_vec());
    r.row("Sphere Terasplit (sim)", sphere_split.clone());
    let paper_total_h = total(&PAPER_HADOOP_SORT, &PAPER_HADOOP_SPLIT);
    let paper_total_s = total(&PAPER_SPHERE_SORT, &PAPER_SPHERE_SPLIT);
    let sim_total_h = total(&hadoop_sort, &hadoop_split);
    let sim_total_s = total(&sphere_sort, &sphere_split);
    r.row("Speedup total (paper)", ratio(&paper_total_h, &paper_total_s));
    r.row("Speedup total (sim)", ratio(&sim_total_h, &sim_total_s));

    // Reproduction bands: absolute cells within ±25%, speedups ±20%.
    r.check_band("hadoop_sort", &PAPER_HADOOP_SORT, &hadoop_sort, 0.25);
    r.check_band("sphere_sort", &PAPER_SPHERE_SORT, &sphere_sort, 0.25);
    r.check_band("hadoop_split", &PAPER_HADOOP_SPLIT, &hadoop_split, 0.25);
    r.check_band("sphere_split", &PAPER_SPHERE_SPLIT, &sphere_split, 0.25);
    r.check_band(
        "speedup_total",
        &ratio(&paper_total_h, &paper_total_s),
        &ratio(&sim_total_h, &sim_total_s),
        0.20,
    );

    // The paper's §6.4 scaling claims, relative to the 2-node single-site
    // row: ~41% penalty at 4 nodes / 2 sites, ~82% at 6 nodes / 3 sites.
    let pen4 = sim_total_s[3] / sim_total_s[1] - 1.0;
    let pen6 = sim_total_s[5] / sim_total_s[1] - 1.0;
    r.note(&format!(
        "Sphere WAN penalty vs 2-node row: 4-node {:.0}% (paper ~41%), 6-node {:.0}% (paper ~82%)",
        pen4 * 100.0,
        pen6 * 100.0
    ));
    r.note("who-wins: Sphere at every node count, as in the paper");
    println!("{}", r.render());
    assert!(
        sim_total_h
            .iter()
            .zip(&sim_total_s)
            .all(|(h, s)| h > s),
        "Sphere must win every column"
    );
}

//! §6.3 file-generation reproduction: "The file generation required 212
//! seconds per file per node for Hadoop, which is a throughput of
//! 440 Mb/s per node.  For Sphere, the file generation required 68
//! seconds per node, which is a throughput of 1.1 Gb/s per node."
//!
//! Also times the REAL record generator + storage write path at MB
//! scale (the same code the e2e example runs).
//!
//!     cargo bench --bench bench_filegen

use sector_sphere::bench::{time_fn, Report};
use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::simulate_hadoop_filegen;
use sector_sphere::mining::terasort::generate_records;
use sector_sphere::sector::{MemStorage, Storage};
use sector_sphere::sphere::simjob::simulate_sphere_filegen;
use sector_sphere::util::bytes::{fmt_rate_bytes_per_sec, GB};

fn main() {
    let bytes = 10.0 * GB as f64;
    let cfg = SimConfig::lan_default();
    let sphere = simulate_sphere_filegen(&cfg, bytes);
    let hadoop = simulate_hadoop_filegen(&cfg, bytes);

    let cols = vec!["Sphere".to_string(), "Hadoop".to_string(), "ratio".to_string()];
    let mut r = Report::new("§6.3 — file generation, 10 GB per node (seconds)", &cols);
    r.row("paper", vec![68.0, 212.0, 212.0 / 68.0]);
    r.row("sim", vec![sphere, hadoop, hadoop / sphere]);
    r.check_band("filegen", &[68.0, 212.0], &[sphere, hadoop], 0.25);
    r.note(&format!(
        "implied throughput: sphere {} (paper 1.1 Gb/s), hadoop {} (paper 440 Mb/s)",
        fmt_rate_bytes_per_sec(bytes / sphere),
        fmt_rate_bytes_per_sec(bytes / hadoop)
    ));
    println!("{}", r.render());

    // Real generator microbench: how fast this implementation actually
    // synthesizes + stores gensort records (hot path of the examples).
    let n = 100_000; // 10 MB
    let t_gen = time_fn("generate_records(100k)", 1, 5, || generate_records(n, 42));
    let data = generate_records(n, 42);
    let store = MemStorage::new();
    let mut i = 0u32;
    let t_put = time_fn("mem put(10MB)", 1, 5, || {
        i += 1;
        store.put(&format!("f{i}"), &data).unwrap()
    });
    println!(
        "real path: generate {} ; store {}",
        fmt_rate_bytes_per_sec(10.0e6 / t_gen.secs.mean),
        fmt_rate_bytes_per_sec(10.0e6 / t_put.secs.mean)
    );
    assert!(hadoop / sphere > 2.0, "Sphere must generate >2x faster");
}

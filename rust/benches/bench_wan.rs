//! Wide-area transport + churn gate (DESIGN.md §18): the paper's
//! reason for UDT is that stock TCP cannot fill a long-fat pipe, so a
//! `compare` run on the 10 Gbps WAN preset must show Sphere-over-UDT
//! beating Sphere-over-TCP (>1x), with the gap widening as the WAN RTT
//! grows — the `transport = "udt" | "tcp"` knob exercised end to end.
//! Alongside it, the churn-rate sweep axis runs twice and must render
//! byte-identical SweepReport JSON; one FNV hash over both experiments
//! is checked against the committed baseline in `BENCH_wan.json` at
//! the repo root.  Any drift fails the bench (and CI's
//! bench-trajectory job); an intentional recalibration re-runs with
//! `BENCH_WAN_UPDATE=1` and commits the rewritten JSON.
//!
//!     cargo bench --bench bench_wan
//!
//! The emitted JSON carries ONLY deterministic simulation outputs (no
//! wall clock): per-transport makespans, the UDT-over-TCP gains at
//! both RTTs, the churn sweep's fingerprints and per-point records,
//! and the combined determinism hash.  Wall-clock timings are printed
//! to stdout instead.

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::config::TransportKind;
use sector_sphere::routing::hash_name;
use sector_sphere::scenario::{run_scenario, run_sweep, Axis, ScenarioReport, ScenarioSpec, SweepSpec};

/// Marker a bootstrap baseline carries before the first real run.
const UNSET: &str = "UNSET";

fn baseline_path() -> std::path::PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("BENCH_wan.json")
}

/// Pull `"key": value` out of the flat baseline JSON without serde.
fn field<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\": ");
    let start = json.find(&tag)? + tag.len();
    let rest = &json[start..];
    let end = rest.find(&[',', '}'][..])?;
    Some(rest[..end].trim().trim_matches('"'))
}

/// The weather preset's 16-node compare topology under a clear sky —
/// weather stripped so the transport term is the ONLY thing moving
/// between runs — at the given WAN RTT and Sphere transport.
fn wan_compare_spec(transport: TransportKind, rtt_ms: f64) -> ScenarioSpec {
    let mut spec = ScenarioSpec::weather_compare16();
    spec.weather = None;
    spec.name = format!("wan16-{}-rtt{rtt_ms:.0}ms", transport.name());
    spec.topology.wan_rtt_secs = rtt_ms / 1e3;
    spec.cfg.sphere_transport = transport;
    spec
}

fn run_compare_pair(rtt_ms: f64) -> (ScenarioReport, ScenarioReport) {
    let udt_spec = wan_compare_spec(TransportKind::Udt, rtt_ms);
    let tcp_spec = wan_compare_spec(TransportKind::Tcp, rtt_ms);
    let udt = run_scenario(&udt_spec).unwrap_or_else(|e| panic!("{}: {e}", udt_spec.name));
    let tcp = run_scenario(&tcp_spec).unwrap_or_else(|e| panic!("{}: {e}", tcp_spec.name));
    // Determinism: same spec, same report, bit for bit.
    let udt2 = run_scenario(&udt_spec).unwrap();
    assert_eq!(
        format!("{udt:?}"),
        format!("{udt2:?}"),
        "rtt {rtt_ms} ms: the compare run must be byte-identical across reruns"
    );
    (udt, tcp)
}

/// `tcp_makespan / udt_makespan` for the Sphere side of a compare pair.
fn sphere_gain(udt: &ScenarioReport, tcp: &ScenarioReport) -> f64 {
    let u = udt.comparison.as_ref().expect("compare preset ran both engines");
    let t = tcp.comparison.as_ref().expect("compare preset ran both engines");
    // Hadoop never reads `sphere_transport`: its side is the control
    // arm and must not move between the two runs.
    assert_eq!(
        u.hadoop.makespan_secs, t.hadoop.makespan_secs,
        "the transport knob leaked into the Hadoop engine"
    );
    t.sphere.makespan_secs / u.sphere.makespan_secs.max(1e-9)
}

fn main() {
    let mut json = BenchJson::new("wan");
    json.text("bench", "wan");

    // ---- UDT-over-TCP on the 10 Gbps WAN compare preset ----
    let (udt40, tcp40) = run_compare_pair(40.0);
    let gain40 = sphere_gain(&udt40, &tcp40);
    let c40 = udt40.comparison.as_ref().unwrap();
    println!(
        "rtt 40ms: sphere/udt {:.1} s, sphere/tcp {:.1} s, hadoop {:.1} s \
         -> udt-over-tcp {gain40:.2}x, sphere-over-hadoop {:.2}x",
        c40.sphere.makespan_secs,
        tcp40.comparison.as_ref().unwrap().sphere.makespan_secs,
        c40.hadoop.makespan_secs,
        c40.speedup
    );
    // The acceptance gate: at 10 Gbps WAN the UDT run must beat the
    // TCP run outright, and the UDT-transported Sphere must still beat
    // Hadoop (the paper's headline, now conditional on the transport).
    assert!(
        gain40 > 1.0,
        "UDT must beat 2008-era TCP on the 10 Gbps WAN preset (got {gain40:.3}x)"
    );
    assert!(
        c40.speedup > 1.0,
        "Sphere-over-UDT must still beat Hadoop on the WAN preset (got {:.3}x)",
        c40.speedup
    );

    // ---- the gap widens with RTT (long-fat-network asymmetry) ----
    let (udt120, tcp120) = run_compare_pair(120.0);
    let gain120 = sphere_gain(&udt120, &tcp120);
    println!("rtt 120ms: udt-over-tcp {gain120:.2}x");
    assert!(
        gain120 > gain40,
        "TCP's window cap scales as 1/RTT while UDT holds the link: the \
         UDT-over-TCP gain must widen from 40 ms ({gain40:.2}x) to 120 ms \
         ({gain120:.2}x)"
    );
    json.num("udt_sphere_makespan_secs", c40.sphere.makespan_secs)
        .num(
            "tcp_sphere_makespan_secs",
            tcp40.comparison.as_ref().unwrap().sphere.makespan_secs,
        )
        .num("hadoop_makespan_secs", c40.hadoop.makespan_secs)
        .num("udt_over_tcp_gain_rtt40", gain40)
        .num("udt_over_tcp_gain_rtt120", gain120)
        .num("udt_compare_speedup", c40.speedup);
    let h_transport = hash_name(&format!(
        "{:.9}|{:.9}|{:.9}|{:.9}",
        c40.sphere.makespan_secs,
        tcp40.comparison.as_ref().unwrap().sphere.makespan_secs,
        udt120.comparison.as_ref().unwrap().sphere.makespan_secs,
        tcp120.comparison.as_ref().unwrap().sphere.makespan_secs,
    ));
    let t = time_fn("wan_compare_udt", 0, 2, || {
        run_scenario(&wan_compare_spec(TransportKind::Udt, 40.0)).unwrap()
    });
    println!("wan_compare_udt: {:.0} ms wall per run", t.secs.mean * 1e3);

    // ---- churn-rate sweep over the 32-node churn preset ----
    let sweep = SweepSpec {
        name: "churn-rate-wan32".into(),
        base: ScenarioSpec::churn_wan32(),
        workers: 2,
        axes: vec![Axis::ChurnRate(vec![0.0, 4.0, 8.0])],
    };
    let a = run_sweep(&sweep).unwrap_or_else(|e| panic!("churn sweep: {e}"));
    let b = run_sweep(&sweep).unwrap_or_else(|e| panic!("churn sweep rerun: {e}"));
    assert_eq!(
        a.to_json(),
        b.to_json(),
        "churn sweep: the SweepReport JSON must be byte-identical across runs"
    );
    assert_eq!(a.records.len(), 3, "churn grid is the 3 swept rates");
    let calm = a.records[0].makespan_secs;
    for r in &a.records {
        println!(
            "  churn_rate={:<4} makespan {:>9.1} s  ({})",
            r.axes[0].1, r.makespan_secs, r.fingerprint
        );
        assert!(!r.determinism.is_empty(), "every point carries its digest");
        // Losing nodes mid-run can only cost time: re-runs and
        // re-replication contend with the job (rate 0 is the floor).
        assert!(
            r.makespan_secs >= calm * (1.0 - 1e-9),
            "churn_rate={} finished faster ({:.1} s) than the churnless \
             floor ({calm:.1} s)",
            r.axes[0].1,
            r.makespan_secs
        );
    }
    let h_churn = hash_name(&a.to_json());
    json.int("churn_points", a.records.len() as u64)
        .text("churn_grid_fingerprint", &a.grid_fingerprint)
        .raw("churn_records", &a.records_json());

    let hash = format!("{:016x}-{:016x}", h_transport, h_churn);
    json.text("determinism_hash", &hash);

    // ---- regression gate against the committed baseline ----
    // Read the committed file BEFORE overwriting it, and write the new
    // numbers BEFORE any drift panic — the CI artifact must carry the
    // new values even when the gate trips.
    let committed = std::fs::read_to_string(baseline_path());
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_wan.json not written: {e}"),
    }
    let update = std::env::var("BENCH_WAN_UPDATE").is_ok();
    match committed {
        Ok(committed) => {
            let base_hash = field(&committed, "determinism_hash").unwrap_or(UNSET);
            if base_hash == UNSET {
                println!(
                    "baseline is a bootstrap placeholder: commit the rewritten \
                     BENCH_wan.json to arm the drift gate \
                     (README 'Calibration & baselines')"
                );
            } else if update {
                println!("BENCH_WAN_UPDATE set: accepting new baseline {hash}");
            } else {
                let mut drift = Vec::new();
                if base_hash != hash {
                    drift.push(format!("determinism hash {base_hash} -> {hash}"));
                }
                for key in ["churn_points", "churn_grid_fingerprint"] {
                    let old = field(&committed, key).unwrap_or("?");
                    let new_json = json.render();
                    let new = field(&new_json, key).unwrap_or("?");
                    if old != new {
                        drift.push(format!("{key} {old} -> {new}"));
                    }
                }
                if !drift.is_empty() {
                    for d in &drift {
                        eprintln!("DRIFT: {d}");
                    }
                    panic!(
                        "bench_wan drifted from the committed baseline — if \
                         intentional, rerun with BENCH_WAN_UPDATE=1 and commit \
                         the rewritten BENCH_wan.json"
                    );
                }
                println!("baseline check: churn grid and determinism hash match");
            }
        }
        Err(_) => println!("no committed baseline found; wrote a fresh one"),
    }
}

//! Colocation engine gate (DESIGN.md §11): the flagship colocated
//! preset — 128-node Terasort sharing disks and WAN tiers with a
//! three-tenant client stream through the scale128-class fault plan —
//! run twice for the determinism contract (byte-identical serialized
//! reports), then once with speculation disabled to gate the
//! acceptance property: under the straggler fault plan, speculative
//! re-execution must REDUCE the terasort makespan.
//!
//!     cargo bench --bench bench_colocate
//!
//! Emits BENCH_colocate.json at the repo root (wall clock, job
//! makespan with/without speculation, speculation counters, per-tenant
//! p99 and colocation deltas).

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::scenario::{run_scenario, ScenarioSpec};

fn main() {
    let mut json = BenchJson::new("colocate");
    json.text("bench", "colocate");

    // Determinism gate: same spec, byte-identical serialized report.
    let spec = ScenarioSpec::colocate_scale128();
    let a = run_scenario(&spec).expect("colocate_scale128 runs");
    let b = run_scenario(&spec).expect("colocate_scale128 reruns");
    assert_eq!(a, b, "colocate_scale128 must be deterministic");
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "serialized reports must be byte-identical"
    );
    let t = time_fn("colocate_scale128", 1, 3, || run_scenario(&spec).unwrap());

    let co = a.colocation.as_ref().expect("joint view present");
    let traffic = a.traffic.as_ref().expect("SLO table present");
    println!(
        "colocate_scale128: job {} in {:.1} simulated s, traffic {} reqs in {:.1} s \
         ({:.0} ms wall)",
        a.workload,
        co.job_makespan_secs,
        traffic.requests,
        traffic.makespan_secs,
        t.secs.mean * 1e3
    );
    for (name, end) in &co.stage_ends {
        println!("  stage {name:<18} ended {end:>8.1} s");
    }
    for slo in &traffic.tenants {
        println!(
            "  {:<12} p50 {:>8.1} ms  p95 {:>8.1} ms  p99 {:>8.1} ms  {:>7.1} rps",
            slo.name, slo.p50_ms, slo.p95_ms, slo.p99_ms, slo.throughput_rps
        );
        json.num(&format!("p50_ms_{}", slo.name), slo.p50_ms)
            .num(&format!("p95_ms_{}", slo.name), slo.p95_ms)
            .num(&format!("p99_ms_{}", slo.name), slo.p99_ms);
    }
    for d in &co.tenant_deltas {
        println!(
            "  colo cost {:<12} p50 {:+8.1} ms  p95 {:+8.1} ms  p99 {:+8.1} ms",
            d.name, d.p50_delta_ms, d.p95_delta_ms, d.p99_delta_ms
        );
        json.num(&format!("p99_delta_ms_{}", d.name), d.p99_delta_ms);
    }
    json.num("wall_ms", t.secs.mean * 1e3)
        .num("wall_p99_ms", t.secs.p99 * 1e3)
        .num("job_makespan_secs", co.job_makespan_secs)
        .num("traffic_makespan_secs", traffic.makespan_secs)
        .int("events", a.events)
        .int("segments", a.segments as u64)
        .int("requests", traffic.requests)
        .int("completed", traffic.completed)
        .int("rejected", traffic.rejected)
        .int("unavailable", traffic.unavailable)
        .int("reassignments", a.reassignments)
        .int("speculative_launched", a.speculative_launched)
        .int("speculative_won", a.speculative_won);

    // Acceptance gate: with the straggler fault plan enabled,
    // speculation must cut the terasort makespan vs speculative=off.
    let mut off_spec = ScenarioSpec::colocate_scale128();
    off_spec.colocation.speculative = false;
    let off_a = run_scenario(&off_spec).expect("speculation-off run");
    let off_b = run_scenario(&off_spec).expect("speculation-off rerun");
    assert_eq!(off_a, off_b, "speculation-off runs stay deterministic");
    let off_co = off_a.colocation.as_ref().expect("joint view present");
    println!(
        "speculation: {} launched, {} won; job makespan {:.1} s (on) vs {:.1} s (off)",
        a.speculative_launched,
        a.speculative_won,
        co.job_makespan_secs,
        off_co.job_makespan_secs
    );
    assert!(a.speculative_launched > 0, "the 4x straggler must trigger backups");
    assert!(a.speculative_won > 0, "backups must win against the 4x straggler");
    assert_eq!(off_a.speculative_launched, 0, "knob off means no backups");
    assert!(
        co.job_makespan_secs < off_co.job_makespan_secs,
        "speculative execution must reduce terasort makespan under the \
         straggler plan: {:.2} s (on) vs {:.2} s (off)",
        co.job_makespan_secs,
        off_co.job_makespan_secs
    );
    json.num("job_makespan_secs_spec_off", off_co.job_makespan_secs)
        .num(
            "speculation_makespan_gain_secs",
            off_co.job_makespan_secs - co.job_makespan_secs,
        );

    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_colocate.json not written: {e}"),
    }
}

//! Table 3 reproduction: Angle clustering time vs workload size —
//! "the time spent clustering using Sphere scales as the number of
//! files managed by Sector increases."
//!
//! The small cells also run for REAL through the full pipeline (Sector
//! upload -> Sphere feature UDF -> k-means windows); the 1e6/1e8-record
//! cells use the calibrated cost model (the paper's own numbers come
//! from a 300,000-file production archive).
//!
//!     cargo bench --bench bench_table3

use sector_sphere::bench::Report;
use sector_sphere::mining::{run_pipeline, simulate_angle_clustering, AngleScenario};
use sector_sphere::sector::SectorCloud;
use sector_sphere::util::bytes::fmt_duration_secs;

// Paper Table 3: (records, sector files, seconds).
const PAPER: [(f64, f64, f64); 4] = [
    (500.0, 1.0, 1.9),
    (1000.0, 3.0, 4.2),
    (1.0e6, 2850.0, 85.0 * 60.0),
    (1.0e8, 300_000.0, 178.0 * 3600.0),
];

fn main() {
    let cols: Vec<String> = PAPER
        .iter()
        .map(|(r, f, _)| format!("{r:.0}r/{f:.0}f"))
        .collect();
    let paper: Vec<f64> = PAPER.iter().map(|c| c.2).collect();
    let model: Vec<f64> = PAPER
        .iter()
        .map(|(r, f, _)| simulate_angle_clustering(*r, *f))
        .collect();

    let mut rep = Report::new("Table 3 — Angle clustering time vs workload", &cols);
    rep.row("paper (s)", paper.clone());
    rep.row("model (s)", model.clone());
    rep.check_band("clustering_time", &paper, &model, 0.30);
    for (i, (r, f, p)) in PAPER.iter().enumerate() {
        rep.note(&format!(
            "{:>12} records / {:>7} files: paper {:>10}, model {:>10}",
            r,
            f,
            fmt_duration_secs(*p),
            fmt_duration_secs(model[i])
        ));
    }

    // Real-path spot check: run the two small cells through the actual
    // Sector+Sphere pipeline and confirm the same scaling direction.
    let mut real = Vec::new();
    for (sensors, windows) in [(1u32, 2u64), (3u32, 2u64)] {
        let cloud = SectorCloud::builder().nodes(4).seed(33).build().unwrap();
        let scenario = AngleScenario {
            sensors,
            sources_per_sensor: 50,
            windows,
            packets_per_source: 25,
            anomalies: vec![],
            seed: 33,
            k: 4,
        };
        let t0 = std::time::Instant::now();
        let report = run_pipeline(&cloud, &scenario, None).expect("pipeline");
        real.push((report.feature_files, t0.elapsed().as_secs_f64()));
    }
    rep.note(&format!(
        "real-path spot check: {} files -> {:.2}s, {} files -> {:.2}s (monotone in files: {})",
        real[0].0,
        real[0].1,
        real[1].0,
        real[1].1,
        real[1].1 > real[0].1
    ));
    println!("{}", rep.render());
    assert!(
        model.windows(2).all(|w| w[0] < w[1]),
        "time grows with workload"
    );
}

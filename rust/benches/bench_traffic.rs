//! Traffic-engine scaling: wall-clock cost of serving the flagship
//! multi-tenant request stream (DESIGN.md §10).  An engineering gate,
//! not a paper table: the "millions of users" north star dies if
//! per-request overhead grows with the population, so this bench
//! sweeps the client population at fixed request count, then runs the
//! full 128-node faulted preset twice to assert the determinism
//! contract and record the SLO headline numbers.
//!
//!     cargo bench --bench bench_traffic
//!
//! Emits BENCH_traffic.json at the repo root (wall clock, simulated
//! makespan, per-tenant p99, completion/rejection counters).

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::scenario::{run_scenario, ScenarioSpec};

fn main() {
    let mut json = BenchJson::new("traffic");
    json.text("bench", "traffic");

    // Population sweep: same request count, growing client population
    // (sessions are lazy, so cost must stay roughly flat).
    println!("traffic engine, population sweep (20k requests, 128 nodes):");
    println!(
        "{:>10} {:>9} {:>11} {:>13} {:>11}",
        "clients", "events", "wall ms", "requests/sec", "makespan s"
    );
    let mut wall_ms = Vec::new();
    for clients in [10_000usize, 100_000, 1_000_000] {
        let mut spec = ScenarioSpec::traffic_scale128();
        {
            let t = spec.traffic.as_mut().unwrap();
            t.clients = clients;
            t.requests = 20_000;
        }
        let report = run_scenario(&spec).expect("traffic scenario runs");
        let t = time_fn(&spec.name, 1, 3, || run_scenario(&spec).unwrap());
        let traffic = report.traffic.as_ref().expect("traffic report");
        let rps_wall = traffic.requests as f64 / t.secs.mean.max(1e-9);
        wall_ms.push(t.secs.mean * 1e3);
        println!(
            "{:>10} {:>9} {:>11.1} {:>13.0} {:>11.2}",
            clients, report.events, t.secs.mean * 1e3, rps_wall, report.makespan_secs
        );
        json.num(&format!("sweep_wall_ms_{clients}"), t.secs.mean * 1e3)
            .num(&format!("sweep_requests_per_wall_sec_{clients}"), rps_wall);
    }
    let growth = wall_ms.last().unwrap() / wall_ms.first().unwrap().max(1e-9);
    println!("wall-clock growth 10k -> 1M clients: {growth:.2}x");
    // Population-independent cost would be ~1x; O(clients) scaling
    // would be ~100x. The bound leaves headroom for noisy shared CI
    // runners while still catching accidental per-client work.
    assert!(
        growth < 20.0,
        "per-request cost must not scale with the population ({growth:.2}x)"
    );
    json.num("population_growth_10k_to_1m", growth);

    // The flagship: 150k requests, 200k clients, three tenants, the
    // scale128 fault plan — plus the determinism contract.
    let spec = ScenarioSpec::traffic_scale128();
    let a = run_scenario(&spec).expect("traffic_scale128 runs");
    let b = run_scenario(&spec).expect("traffic_scale128 reruns");
    assert_eq!(a, b, "traffic_scale128 must be deterministic");
    let t = time_fn("traffic_scale128", 1, 3, || run_scenario(&spec).unwrap());
    let traffic = a.traffic.as_ref().expect("traffic report");
    println!(
        "\ntraffic_scale128: {} requests in {:.1} simulated s ({:.0} ms wall), \
         {} completed, {} rejected, {} unavailable",
        traffic.requests,
        traffic.makespan_secs,
        t.secs.mean * 1e3,
        traffic.completed,
        traffic.rejected,
        traffic.unavailable
    );
    json.num("scale128_wall_ms", t.secs.mean * 1e3)
        .num("scale128_wall_p99_ms", t.secs.p99 * 1e3)
        .num("scale128_makespan_secs", traffic.makespan_secs)
        .int("scale128_requests", traffic.requests)
        .int("scale128_completed", traffic.completed)
        .int("scale128_rejected", traffic.rejected)
        .int("scale128_unavailable", traffic.unavailable)
        .int("scale128_events", a.events)
        .int("scale128_reassignments", traffic.reassignments)
        .num("scale128_meta_hit_rate", traffic.meta_hit_rate)
        .num("scale128_conn_hit_rate", traffic.conn_hit_rate);
    for slo in &traffic.tenants {
        println!(
            "  {:<12} p50 {:>8.1} ms  p95 {:>8.1} ms  p99 {:>8.1} ms  {:>7.1} rps",
            slo.name, slo.p50_ms, slo.p95_ms, slo.p99_ms, slo.throughput_rps
        );
        json.num(&format!("p50_ms_{}", slo.name), slo.p50_ms)
            .num(&format!("p95_ms_{}", slo.name), slo.p95_ms)
            .num(&format!("p99_ms_{}", slo.name), slo.p99_ms)
            .num(&format!("rps_{}", slo.name), slo.throughput_rps);
    }
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_traffic.json not written: {e}"),
    }
}

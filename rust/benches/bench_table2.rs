//! Table 2 reproduction: single-rack (LAN) Terasort + Terasplit,
//! Sphere vs Hadoop, 10 GB/node over 1..8 nodes.
//!
//!     cargo bench --bench bench_table2

use sector_sphere::bench::Report;
use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::simulate_hadoop_row;
use sector_sphere::sphere::simjob::simulate_sphere_row;
use sector_sphere::topology::Testbed;
use sector_sphere::util::bytes::GB;

// Paper Table 2 rows (seconds), nodes 1..8.
const PAPER_HADOOP_SORT: [f64; 8] = [645.0, 766.0, 768.0, 773.0, 815.0, 882.0, 901.0, 1000.0];
const PAPER_SPHERE_SORT: [f64; 8] = [408.0, 409.0, 410.0, 429.0, 430.0, 436.0, 440.0, 443.0];
const PAPER_HADOOP_SPLIT: [f64; 8] =
    [141.0, 266.0, 410.0, 544.0, 671.0, 901.0, 1133.0, 1250.0];
const PAPER_SPHERE_SPLIT: [f64; 8] = [96.0, 221.0, 350.0, 462.0, 560.0, 663.0, 754.0, 855.0];

fn main() {
    let bytes = 10.0 * GB as f64;
    let cfg = SimConfig::lan_default();
    let cols: Vec<String> = (1..=8).map(|n| format!("n={n}")).collect();

    let mut sphere_sort = Vec::new();
    let mut hadoop_sort = Vec::new();
    let mut sphere_split = Vec::new();
    let mut hadoop_split = Vec::new();
    for n in 1..=8 {
        let t = Testbed::lan_testbed(n);
        let s = simulate_sphere_row(&t, &cfg, bytes);
        let h = simulate_hadoop_row(&t, &cfg, bytes);
        sphere_sort.push(s.terasort_secs);
        sphere_split.push(s.terasplit_secs);
        hadoop_sort.push(h.terasort_secs);
        hadoop_split.push(h.terasplit_secs);
    }
    let ratio =
        |a: &[f64], b: &[f64]| -> Vec<f64> { a.iter().zip(b).map(|(x, y)| x / y).collect() };

    let mut r = Report::new("Table 2 — LAN Terasort/Terasplit (10 GB/node, 8-node rack)", &cols);
    r.row("Hadoop Terasort (paper)", PAPER_HADOOP_SORT.to_vec());
    r.row("Hadoop Terasort (sim)", hadoop_sort.clone());
    r.row("Sphere Terasort (paper)", PAPER_SPHERE_SORT.to_vec());
    r.row("Sphere Terasort (sim)", sphere_sort.clone());
    r.row("Hadoop Terasplit (paper)", PAPER_HADOOP_SPLIT.to_vec());
    r.row("Hadoop Terasplit (sim)", hadoop_split.clone());
    r.row("Sphere Terasplit (paper)", PAPER_SPHERE_SPLIT.to_vec());
    r.row("Sphere Terasplit (sim)", sphere_split.clone());
    r.row(
        "Speedup sort (paper)",
        ratio(&PAPER_HADOOP_SORT, &PAPER_SPHERE_SORT),
    );
    r.row("Speedup sort (sim)", ratio(&hadoop_sort, &sphere_sort));
    r.row(
        "Speedup split (paper)",
        ratio(&PAPER_HADOOP_SPLIT, &PAPER_SPHERE_SPLIT),
    );
    r.row("Speedup split (sim)", ratio(&hadoop_split, &sphere_split));

    r.check_band("hadoop_sort", &PAPER_HADOOP_SORT, &hadoop_sort, 0.25);
    r.check_band("sphere_sort", &PAPER_SPHERE_SORT, &sphere_sort, 0.25);
    r.check_band("hadoop_split", &PAPER_HADOOP_SPLIT, &hadoop_split, 0.25);
    r.check_band("sphere_split", &PAPER_SPHERE_SPLIT, &sphere_split, 0.25);
    r.note("paper bands: sort speedup 1.6-2.3x, split speedup 1.2-1.5x");
    let sort_speedups = ratio(&hadoop_sort, &sphere_sort);
    let split_speedups = ratio(&hadoop_split, &sphere_split);
    r.note(&format!(
        "sim bands: sort {:.1}-{:.1}x, split {:.1}-{:.1}x",
        sort_speedups.iter().cloned().fold(f64::MAX, f64::min),
        sort_speedups.iter().cloned().fold(f64::MIN, f64::max),
        split_speedups.iter().cloned().fold(f64::MAX, f64::min),
        split_speedups.iter().cloned().fold(f64::MIN, f64::max),
    ));
    println!("{}", r.render());
    assert!(sort_speedups.iter().all(|&s| s > 1.0), "Sphere wins sort");
    assert!(split_speedups.iter().all(|&s| s > 1.0), "Sphere wins split");
}

//! Angle pipeline gate (DESIGN.md §13): run both staged-Angle presets
//! — the paper's four-sensor-site WAN deployment and Table 3's
//! 300,000-file scale under the full fault plan — twice each for the
//! determinism contract, then gate the acceptance properties:
//!
//!   * recall 1.0 on the planted §7.1 scan/exfil regime shifts, in the
//!     fault-free preset AND under the crash/straggler plan;
//!   * the staged mining cost within the documented band of the
//!     retained Table 3 oracle at the 300k-file point;
//!   * the fault plan costs makespan (faulted vs fault-free clone) and
//!     the 4x straggler's window is rescued by speculation.
//!
//!     cargo bench --bench bench_angle
//!
//! Emits BENCH_angle.json at the repo root: an FNV determinism hash of
//! each serialized report, recalls, makespans, per-tier model bytes
//! and speculation counters (wall clock printed to stdout only).

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::routing::hash_name;
use sector_sphere::scenario::{run_scenario, ScenarioReport, ScenarioSpec};

fn run_preset(name: &str, spec: &ScenarioSpec, json: &mut BenchJson) -> (ScenarioReport, u64) {
    let a = run_scenario(spec).unwrap_or_else(|e| panic!("{name}: {e}"));
    let b = run_scenario(spec).unwrap_or_else(|e| panic!("{name} rerun: {e}"));
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "{name}: serialized reports must be byte-identical"
    );
    let hash = hash_name(&format!("{a:?}"));
    let t = time_fn(name, 1, 3, || run_scenario(spec).unwrap());
    let an = a.angle.clone().expect("angle preset reports the mining side");
    println!(
        "{name}: {} windows / {} files in {:.1} simulated s ({:.0} ms wall), \
         recall {:.2}, spec {}/{}",
        an.windows,
        an.files,
        a.makespan_secs,
        t.secs.mean * 1e3,
        an.recall,
        a.speculative_won,
        a.speculative_launched,
    );
    println!(
        "  emergent found {:?} vs planted {:?}; features {:.3} GB; models \
         nic {:.1} / rack {:.1} / wan {:.1} KB; staged {:.0} s vs oracle {:.0} s",
        an.emergent_found,
        an.emergent_planted,
        an.feature_gbytes,
        an.model_tier.nic / 1e3,
        an.model_tier.rack / 1e3,
        an.model_tier.wan / 1e3,
        an.staged_work_secs,
        an.oracle_secs,
    );
    assert_eq!(
        an.recall, 1.0,
        "{name}: every planted regime shift must be detected (found {:?})",
        an.emergent_found
    );
    assert!(a.makespan_secs > 0.0, "{name}: empty makespan");
    json.num(&format!("{name}_makespan_secs"), a.makespan_secs)
        .num(&format!("{name}_recall"), an.recall)
        .num(&format!("{name}_staged_work_secs"), an.staged_work_secs)
        .num(&format!("{name}_oracle_secs"), an.oracle_secs)
        .num(&format!("{name}_feature_gbytes"), an.feature_gbytes)
        .num(&format!("{name}_model_wan_kbytes"), an.model_tier.wan / 1e3)
        .int(&format!("{name}_events"), a.events)
        .int(&format!("{name}_segments"), a.segments as u64)
        .int(&format!("{name}_spec_launched"), a.speculative_launched)
        .int(&format!("{name}_spec_won"), a.speculative_won);
    (a, hash)
}

fn main() {
    let mut json = BenchJson::new("angle");
    json.text("bench", "angle");

    let (wan4, h_wan4) = run_preset("angle_wan4", &ScenarioSpec::angle_wan4(), &mut json);
    assert_eq!(wan4.faults_injected, 0, "the wan4 preset is fault-free");

    let (s128, h_s128) =
        run_preset("angle_scale128", &ScenarioSpec::angle_scale128(), &mut json);
    assert_eq!(s128.nodes_crashed, 1, "the scale128 crash fired");
    assert!(
        s128.speculative_launched > 0 && s128.speculative_won > 0,
        "the 4x straggler hosts a window: its cluster task must be rescued \
         by a winning backup ({} launched, {} won)",
        s128.speculative_launched,
        s128.speculative_won
    );

    // Calibration gate at Table 3's 300k-file point: the staged model's
    // serialized mining work stays within the documented band of the
    // oracle (DESIGN.md §13 — per-file term identical, per-record term
    // scaled by observed k-means iterations, so the ratio sits in
    // [0.75, 1.25] where the file term dominates).
    let an = s128.angle.as_ref().unwrap();
    let ratio = an.staged_work_secs / an.oracle_secs;
    println!("calibration at 300k files: staged/oracle = {ratio:.3}");
    assert!(
        (0.75..=1.25).contains(&ratio),
        "staged/oracle = {ratio:.3} left the documented [0.75, 1.25] band"
    );
    json.num("calibration_ratio_300k", ratio);

    // Makespan gate: the fault plan must cost time against a fault-free
    // clone of the same workload (crash re-homing + the straggler's
    // window, even speculated, are not free).
    let mut clean = ScenarioSpec::angle_scale128();
    clean.name = "angle-scale128-clean".into();
    clean.faults.clear();
    let clean_run = run_scenario(&clean).expect("fault-free clone runs");
    println!(
        "fault plan cost: {:.1} s faulted vs {:.1} s clean",
        s128.makespan_secs, clean_run.makespan_secs
    );
    assert!(
        s128.makespan_secs > clean_run.makespan_secs,
        "faults must cost makespan: {:.1} vs {:.1}",
        s128.makespan_secs,
        clean_run.makespan_secs
    );
    json.num("angle_scale128_clean_makespan_secs", clean_run.makespan_secs);

    json.text(
        "determinism_hash",
        &format!("{h_wan4:016x}-{h_s128:016x}"),
    );
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_angle.json not written: {e}"),
    }
}

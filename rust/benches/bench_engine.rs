//! Engine-core hot-path gate (DESIGN.md §14): a synthetic 128-node
//! churn workload driven directly over the NetSim + EventQueue
//! substrate — the inner loop every scenario engine now shares — run
//! once with incremental component-scoped fair-share recomputation and
//! once with the retained `set_full_recompute` baseline (the
//! pre-optimization behavior), in the same process.  The speedup is a
//! machine-independent ratio and must be >= 10x; the completion-order
//! determinism hash must be identical across two incremental runs and
//! match the committed baseline in `BENCH_engine.json` at the repo
//! root.  Intentional recalibration: rerun with `BENCH_ENGINE_UPDATE=1`
//! and commit the rewritten JSON.
//!
//!     cargo bench --bench bench_engine
//!
//! The workload is 32 racks x 4 nodes; each node streams a sequence of
//! rack-local flows (next starts when the previous completes), so the
//! allocator sees constant churn but every connected component stays
//! rack-sized — exactly the structure the incremental path exploits,
//! and exactly what a scenario shuffle wave looks like.  Wall-clock
//! throughput is printed and emitted for trajectory tracking but not
//! gated; the gate is the in-process ratio and the hash.

use std::collections::BTreeMap;

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::routing::hash_name;
use sector_sphere::sim::event::EventQueue;
use sector_sphere::sim::netsim::{FlowId, LinkId, NetProfile, NetSim};
use sector_sphere::util::rng::Pcg64;

const RACKS: usize = 32;
const NODES_PER_RACK: usize = 4;
const NODES: usize = RACKS * NODES_PER_RACK;
const FLOWS_PER_NODE: usize = 40;

/// Marker a bootstrap baseline carries before the first real run.
const UNSET: &str = "UNSET";

fn baseline_path() -> std::path::PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("BENCH_engine.json")
}

/// Pull `"key": value` out of the flat baseline JSON without serde.
fn field<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\": ");
    let start = json.find(&tag)? + tag.len();
    let rest = &json[start..];
    let end = rest.find(&[',', '}'][..])?;
    Some(rest[..end].trim().trim_matches('"'))
}

struct Churn {
    events: u64,
    digest: String,
    profile: NetProfile,
}

/// One full churn run: every node pushes `FLOWS_PER_NODE` rack-local
/// flows back to back through the min(queue, network) interleave the
/// engine core uses.  Deterministic in the fixed seed; `with_digest`
/// records (flow id, completion time) for the determinism hash.
fn churn(full: bool, with_digest: bool) -> Churn {
    let mut net = NetSim::with_capacity(2 * NODES + RACKS);
    net.set_full_recompute(full);
    let up: Vec<LinkId> = (0..NODES).map(|_| net.add_link(1e9)).collect();
    let down: Vec<LinkId> = (0..NODES).map(|_| net.add_link(1e9)).collect();
    let rack: Vec<LinkId> = (0..RACKS).map(|_| net.add_link(10e9)).collect();
    let mut rng = Pcg64::new(0xE27_61B5);
    let mut q: EventQueue<usize> = EventQueue::with_capacity(NODES + 8);
    for src in 0..NODES {
        q.push_at(rng.gen_range_f64(0.0, 1e-3), src);
    }
    let mut left = vec![FLOWS_PER_NODE; NODES];
    let mut by_flow: BTreeMap<FlowId, usize> = BTreeMap::new();
    let mut events: u64 = 0;
    let mut digest = String::new();
    let mut batch: Vec<usize> = Vec::new();
    loop {
        let tq = q.peek_time();
        let tn = net.next_completion().map(|(t, _)| t);
        let next = match (tq, tn) {
            (None, None) => break,
            (Some(a), None) => a,
            (None, Some(b)) => b,
            (Some(a), Some(b)) => a.min(b),
        };
        for fid in net.advance_to(next) {
            events += 1;
            let src = by_flow.remove(&fid).expect("tracked flow");
            if with_digest {
                digest.push_str(&format!("{}:{next:.6};", fid.0));
            }
            if left[src] > 0 {
                q.push_at(next, src);
            }
        }
        if q.peek_time() == Some(next) {
            batch.clear();
            q.pop_simultaneous(&mut batch);
            for src in batch.drain(..) {
                events += 1;
                if left[src] == 0 {
                    continue;
                }
                left[src] -= 1;
                let r = src / NODES_PER_RACK;
                let dst = r * NODES_PER_RACK + rng.gen_range(NODES_PER_RACK as u64) as usize;
                let path = [up[src], rack[r], down[dst]];
                let fid = net.start_flow(
                    &path,
                    rng.gen_range_f64(1e6, 64e6),
                    rng.gen_range_f64(0.2e9, 2.0e9),
                );
                by_flow.insert(fid, src);
            }
        }
    }
    assert_eq!(net.active_flows(), 0, "churn drained");
    Churn {
        events,
        digest,
        profile: net.profile(),
    }
}

fn main() {
    // Determinism: two incremental runs, identical completion digests.
    let a = churn(false, true);
    let b = churn(false, true);
    assert_eq!(a.digest, b.digest, "completion order must replay exactly");
    let hash = format!("{:016x}", hash_name(&a.digest));
    let events = a.events;
    assert_eq!(
        events,
        (NODES * FLOWS_PER_NODE * 2) as u64,
        "every start and every completion counted once"
    );

    // Throughput: incremental vs the retained full-recompute baseline.
    let t_inc = time_fn("engine_incremental", 1, 3, || churn(false, false).events);
    let t_full = time_fn("engine_full_recompute", 1, 2, || churn(true, false).events);
    let inc_eps = events as f64 / t_inc.secs.mean;
    let full_eps = events as f64 / t_full.secs.mean;
    let speedup = inc_eps / full_eps;
    println!(
        "engine churn ({NODES} nodes, {events} events): incremental {:.0} ev/s, \
         full-recompute {:.0} ev/s -> {speedup:.1}x",
        inc_eps, full_eps
    );
    assert!(
        speedup >= 10.0,
        "incremental fair-share recomputation must beat the pre-refactor \
         full recompute by >= 10x on the rack-component churn workload \
         (got {speedup:.1}x)"
    );

    let mut json = BenchJson::new("engine");
    json.text("bench", "engine")
        .int("nodes", NODES as u64)
        .int("events", events)
        .num("incremental_events_per_sec", inc_eps)
        .num("full_recompute_events_per_sec", full_eps)
        .num("speedup_vs_full_recompute", speedup)
        // NetSim self-profiling: how much recomputation the incremental
        // path actually did, and how big the touched components were —
        // the trajectory shows WHY the ratio moves, not just that it did.
        .int("netsim_dirty_recomputes", a.profile.dirty_recomputes)
        .int("netsim_full_recomputes", a.profile.full_recomputes)
        .int("netsim_comp_flows_max", a.profile.comp_flows_max)
        .num("netsim_comp_flows_mean", a.profile.comp_flows_mean())
        .text("determinism_hash", &hash);

    // ---- regression gate against the committed baseline ----
    // Read the committed file BEFORE overwriting it, and write the new
    // numbers BEFORE any drift panic, so the CI artifact carries the
    // new values even when the gate trips.
    let committed = std::fs::read_to_string(baseline_path());
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_engine.json not written: {e}"),
    }
    let update = std::env::var("BENCH_ENGINE_UPDATE").is_ok();
    match committed {
        Ok(committed) => {
            let base_hash = field(&committed, "determinism_hash").unwrap_or(UNSET);
            if base_hash == UNSET {
                println!(
                    "baseline is a bootstrap placeholder: commit the rewritten \
                     BENCH_engine.json to arm the drift gate"
                );
            } else if update {
                println!("BENCH_ENGINE_UPDATE set: accepting new baseline {hash}");
            } else if base_hash != hash {
                eprintln!("DRIFT: determinism hash {base_hash} -> {hash}");
                panic!(
                    "bench_engine drifted from the committed baseline — if \
                     intentional, rerun with BENCH_ENGINE_UPDATE=1 and commit \
                     the rewritten BENCH_engine.json"
                );
            } else {
                println!("baseline check: determinism hash matches");
            }
        }
        Err(_) => println!("no committed baseline found; wrote a fresh one"),
    }
}

//! Elastic-serving gate (DESIGN.md §16): run the 512-node
//! `traffic_elastic512` preset — one million requests from a
//! 1.2M-client lazy population, watermark scaler on — twice for the
//! determinism contract, then check the headline claims:
//!
//!   * the run is byte-identical across reruns (FNV hash recorded);
//!   * the watermark policy improves the hot tenant's p99 against the
//!     embedded same-seed static baseline (negative delta);
//!   * re-replication moved real bytes across the link tiers.
//!
//! Drift against the committed `BENCH_elastic.json` at the repo root
//! fails the bench (and CI's bench-trajectory job); an intentional
//! recalibration re-runs with `BENCH_ELASTIC_UPDATE=1` and commits the
//! rewritten JSON.
//!
//!     cargo bench --bench bench_elastic
//!
//! The emitted JSON carries ONLY deterministic simulation outputs (no
//! wall clock), so the file is byte-stable across runs of one build.
//! Wall-clock timings are printed to stdout instead.

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::routing::hash_name;
use sector_sphere::scenario::{run_scenario, ScenarioSpec};

/// Marker a bootstrap baseline carries before the first real run.
const UNSET: &str = "UNSET";

fn baseline_path() -> std::path::PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("BENCH_elastic.json")
}

/// Pull `"key": value` out of the flat baseline JSON without serde.
fn field<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\": ");
    let start = json.find(&tag)? + tag.len();
    let rest = &json[start..];
    let end = rest.find(&[',', '}'][..])?;
    Some(rest[..end].trim().trim_matches('"'))
}

fn main() {
    let mut json = BenchJson::new("elastic");
    json.text("bench", "elastic");

    let spec = ScenarioSpec::traffic_elastic512();
    assert!(
        spec.traffic.as_ref().unwrap().clients >= 1_000_000,
        "the preset must model a million-plus client population"
    );

    let a = run_scenario(&spec).unwrap_or_else(|e| panic!("traffic_elastic512: {e}"));
    let b = run_scenario(&spec).unwrap_or_else(|e| panic!("traffic_elastic512 rerun: {e}"));
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "traffic_elastic512: serialized reports must be byte-identical"
    );
    let hash = format!("{:016x}", hash_name(&format!("{a:?}")));

    let t = a.traffic.as_ref().expect("traffic report");
    let e = a.elasticity.as_ref().expect("elasticity report");
    assert!(t.requests >= 1_000_000, "the preset must drive a million requests");
    assert_eq!(
        t.completed + t.rejected + t.unavailable,
        t.requests,
        "every request must resolve exactly once"
    );
    assert!(
        t.sessions_touched > 0 && t.sessions_touched <= t.requests,
        "lazy sessions must stay bounded by the request count \
         (touched {} of {} clients)",
        t.sessions_touched,
        spec.traffic.as_ref().unwrap().clients
    );
    assert_eq!(e.invariant_violations, 0, "replica invariants must hold");
    assert!(e.grows > 0, "the burst pattern must trigger re-replication");
    assert!(
        e.rereplication.total() > 0.0,
        "re-replication must move real bytes"
    );
    let hot = e
        .tenant_deltas
        .iter()
        .find(|d| d.name == "interactive")
        .expect("hot tenant delta vs the embedded static baseline");
    assert!(
        hot.p99_delta_ms <= 0.0,
        "watermark must not worsen the hot tenant's p99 vs static \
         (delta {:+.2} ms)",
        hot.p99_delta_ms
    );

    let wall = time_fn("traffic_elastic512", 1, 2, || run_scenario(&spec).unwrap());
    println!(
        "traffic_elastic512: {} req in {:.1} s sim ({} grows, {} sheds, \
         {:.2} GB re-replicated) — hot-tenant p99 {:+.2} ms vs static \
         ({:.0} ms wall)",
        t.requests,
        t.makespan_secs,
        e.grows,
        e.sheds,
        e.rereplication.total() / 1e9,
        hot.p99_delta_ms,
        wall.secs.mean * 1e3
    );
    for d in &e.tenant_deltas {
        println!(
            "  {:<12} p50 {:+8.2} ms  p95 {:+8.2} ms  p99 {:+8.2} ms",
            d.name, d.p50_delta_ms, d.p95_delta_ms, d.p99_delta_ms
        );
    }

    json.int("requests", t.requests)
        .int("completed", t.completed)
        .int("rejected", t.rejected)
        .int("unavailable", t.unavailable)
        .int("sessions_touched", t.sessions_touched)
        .num("makespan_secs", t.makespan_secs)
        .int("grows", e.grows)
        .int("sheds", e.sheds)
        .int("drained_sheds", e.drained_sheds)
        .int("peak_replicas", e.peak_replicas)
        .int("final_replicas", e.final_replicas)
        .num("rereplication_nic_gbytes", e.rereplication.nic / 1e9)
        .num("rereplication_rack_gbytes", e.rereplication.rack / 1e9)
        .num("rereplication_wan_gbytes", e.rereplication.wan / 1e9)
        .num("hot_p99_delta_ms", hot.p99_delta_ms)
        .int("events", a.events);
    json.text("determinism_hash", &hash);

    // ---- regression gate against the committed baseline ----
    // Read the committed file BEFORE overwriting it, and write the new
    // numbers BEFORE any drift panic — the CI artifact must carry the
    // new values even when the gate trips, or the failure is only
    // diagnosable from the job log.
    let committed = std::fs::read_to_string(baseline_path());
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_elastic.json not written: {e}"),
    }
    let update = std::env::var("BENCH_ELASTIC_UPDATE").is_ok();
    match committed {
        Ok(committed) => {
            let base_hash = field(&committed, "determinism_hash").unwrap_or(UNSET);
            if base_hash == UNSET {
                println!(
                    "baseline is a bootstrap placeholder: commit the rewritten \
                     BENCH_elastic.json to arm the drift gate"
                );
            } else if update {
                println!("BENCH_ELASTIC_UPDATE set: accepting new baseline {hash}");
            } else {
                let mut drift = Vec::new();
                if base_hash != hash {
                    drift.push(format!("determinism hash {base_hash} -> {hash}"));
                }
                for key in ["hot_p99_delta_ms", "grows", "sheds"] {
                    let old: f64 = field(&committed, key)
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(f64::NAN);
                    let new: f64 = field(&json.render(), key)
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(f64::NAN);
                    if !(old.is_finite() && (old - new).abs() <= 1e-9 * old.abs().max(1.0)) {
                        drift.push(format!("{key} {old} -> {new}"));
                    }
                }
                if !drift.is_empty() {
                    for d in &drift {
                        eprintln!("DRIFT: {d}");
                    }
                    panic!(
                        "bench_elastic drifted from the committed baseline — if \
                         intentional, rerun with BENCH_ELASTIC_UPDATE=1 and commit \
                         the rewritten BENCH_elastic.json"
                    );
                }
                println!("baseline check: elasticity numbers and determinism hash match");
            }
        }
        Err(_) => println!("no committed baseline found; wrote a fresh one"),
    }
}

//! Scenario-engine scaling: wall-clock cost of simulating growing
//! testbeds (DESIGN.md §4, §5).  Not a paper table — an engineering
//! gate: per-event overhead must not dominate as scenarios grow past
//! the paper's 8 nodes, or the "run any scenario you can describe"
//! promise dies at 128.
//!
//!     cargo bench --bench bench_scale

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::scenario::{run_scenario, ScenarioSpec};
use sector_sphere::topology::TopologySpec;
use sector_sphere::util::bytes::GB;

/// Fault-free Terasort at 1 GB/node on a generated layout.
fn spec_for(sites: usize, racks_per_site: usize, nodes_per_rack: usize) -> ScenarioSpec {
    let mut spec = ScenarioSpec::paper_lan8();
    spec.topology = TopologySpec::scale_out(sites, racks_per_site, nodes_per_rack);
    spec.name = format!("scale-{}", spec.topology.nodes());
    spec.workload.as_mut().unwrap().bytes_per_node = 1.0 * GB as f64;
    spec
}

fn main() {
    println!("scenario engine scaling (terasort, 1 GB/node):");
    println!(
        "{:>6} {:>9} {:>11} {:>12} {:>12}",
        "nodes", "events", "wall ms", "events/sec", "makespan s"
    );
    let mut per_event_ms = Vec::new();
    let mut json = BenchJson::new("scale");
    json.text("bench", "scale");
    for (sites, racks, npr) in [(1, 2, 8), (2, 2, 8), (4, 2, 8), (4, 4, 8)] {
        let spec = spec_for(sites, racks, npr);
        let report = run_scenario(&spec).expect("scenario runs");
        let t = time_fn(&spec.name, 1, 3, || run_scenario(&spec).unwrap());
        let events_per_sec = report.events as f64 / t.secs.mean.max(1e-9);
        per_event_ms.push(t.secs.mean * 1e3 / report.events as f64);
        println!(
            "{:>6} {:>9} {:>11.2} {:>12.0} {:>12.1}",
            report.nodes,
            report.events,
            t.secs.mean * 1e3,
            events_per_sec,
            report.makespan_secs
        );
        let n = report.nodes;
        json.num(&format!("wall_ms_{n}"), t.secs.mean * 1e3)
            .int(&format!("events_{n}"), report.events)
            .num(&format!("events_per_sec_{n}"), events_per_sec)
            .num(&format!("makespan_secs_{n}"), report.makespan_secs);
    }
    // The gate: going 16 -> 128 nodes must not blow up per-event cost
    // (quadratic coordination would show a ~64x jump here).
    let growth = per_event_ms.last().unwrap() / per_event_ms.first().unwrap().max(1e-9);
    println!("per-event cost growth 16->128 nodes: {growth:.1}x");
    assert!(
        growth < 40.0,
        "per-event overhead grew {growth:.1}x from 16 to 128 nodes"
    );

    // The full faulted 128-node preset, plus the determinism contract.
    let spec = ScenarioSpec::scale128();
    let a = run_scenario(&spec).expect("scale128 runs");
    let b = run_scenario(&spec).expect("scale128 reruns");
    assert_eq!(a, b, "scale128 must be deterministic");
    println!(
        "\nscale128 with faults: makespan {:.1} s, {} events, {} reassignments, locality {:.0}%",
        a.makespan_secs,
        a.events,
        a.reassignments,
        a.locality_fraction * 100.0
    );
    let t = time_fn("scale128-faults", 1, 3, || run_scenario(&spec).unwrap());
    json.num("per_event_growth_16_to_128", growth)
        .num("scale128_wall_ms", t.secs.mean * 1e3)
        .num("scale128_wall_p99_ms", t.secs.p99 * 1e3)
        .num("scale128_makespan_secs", a.makespan_secs)
        .int("scale128_events", a.events)
        .int("scale128_segments", a.segments as u64)
        .int("scale128_reassignments", a.reassignments);
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_scale.json not written: {e}"),
    }
}

//! Sweep-orchestrator gate (DESIGN.md §17): run both shipped sweep
//! presets — the Fig 5–6 strong-scaling grid and the Sphere-over-Hadoop
//! WAN speedup surface — twice each, assert the SweepReport JSON is
//! byte-identical across runs and the per-point results invariant to
//! the worker count (only the shard/workers bookkeeping fields may
//! move), gate the grid shape (point counts, fig5 monotonicity,
//! speedup > 1 everywhere),
//! then check the FNV determinism hash against the committed baseline
//! in `BENCH_sweep.json` at the repo root.  Any drift fails the bench
//! (and CI's bench-trajectory job); an intentional recalibration
//! re-runs with `BENCH_SWEEP_UPDATE=1` and commits the rewritten JSON.
//!
//!     cargo bench --bench bench_sweep
//!
//! The emitted JSON carries ONLY deterministic simulation outputs (no
//! wall clock): grid fingerprints, per-preset point counts, makespan
//! extrema, the speedup surface extrema, the full per-point record
//! arrays (via `BenchJson::raw`), and one FNV hash over both reports.
//! Wall-clock timings are printed to stdout instead.

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::routing::hash_name;
use sector_sphere::scenario::{run_sweep, SweepReport, SweepSpec};

/// Marker a bootstrap baseline carries before the first real run.
const UNSET: &str = "UNSET";

fn baseline_path() -> std::path::PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("BENCH_sweep.json")
}

/// Pull `"key": value` out of the flat baseline JSON without serde.
fn field<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\": ");
    let start = json.find(&tag)? + tag.len();
    let rest = &json[start..];
    let end = rest.find(&[',', '}'][..])?;
    Some(rest[..end].trim().trim_matches('"'))
}

fn run_preset(name: &str, spec: &SweepSpec, json: &mut BenchJson) -> (SweepReport, u64) {
    let a = run_sweep(spec).unwrap_or_else(|e| panic!("{name}: {e}"));
    let b = run_sweep(spec).unwrap_or_else(|e| panic!("{name} rerun: {e}"));
    assert_eq!(
        a.to_json(),
        b.to_json(),
        "{name}: the SweepReport JSON must be byte-identical across runs"
    );
    // Worker-count invariance: the shard plan changes, the per-point
    // results must not (grid-order aggregation, DESIGN.md §17).
    let mut serial = spec.clone();
    serial.workers = 1;
    let c = run_sweep(&serial).unwrap_or_else(|e| panic!("{name} serial: {e}"));
    for (x, y) in a.records.iter().zip(&c.records) {
        assert_eq!(
            (x.index, &x.fingerprint, &x.determinism, x.makespan_secs),
            (y.index, &y.fingerprint, &y.determinism, y.makespan_secs),
            "{name}: worker count leaked into point #{}",
            x.index
        );
    }
    let hash = hash_name(&a.to_json());
    let t = time_fn(name, 0, 2, || run_sweep(spec).unwrap());
    println!(
        "{name}: {} points, grid {}, {:.0} ms wall per sweep",
        a.records.len(),
        a.grid_fingerprint,
        t.secs.mean * 1e3
    );
    for r in &a.records {
        let assignment: Vec<String> = r.axes.iter().map(|(k, v)| format!("{k}={v}")).collect();
        println!(
            "  #{:<3} {:<32} makespan {:>9.1} s{}",
            r.index,
            assignment.join(","),
            r.makespan_secs,
            r.speedup.map(|s| format!("  speedup {s:.2}x")).unwrap_or_default()
        );
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for r in &a.records {
        lo = lo.min(r.makespan_secs);
        hi = hi.max(r.makespan_secs);
    }
    json.int(&format!("{name}_points"), a.records.len() as u64)
        .text(&format!("{name}_grid_fingerprint"), &a.grid_fingerprint)
        .num(&format!("{name}_min_makespan_secs"), lo)
        .num(&format!("{name}_max_makespan_secs"), hi)
        .raw(&format!("{name}_records"), &a.records_json());
    (a, hash)
}

fn main() {
    let mut json = BenchJson::new("sweep");
    json.text("bench", "sweep");

    // ---- Fig 5-6 strong-scaling grid: point-count + monotonicity ----
    let fig5_spec = SweepSpec::fig5_scaling();
    let (fig5, h_fig5) = run_preset("fig5_scaling", &fig5_spec, &mut json);
    assert_eq!(fig5.records.len(), 6, "fig5 grid is 3 node counts x 2 total sizes");
    // At a fixed total size the per-node share shrinks as nodes grow:
    // makespans must be monotone non-increasing along the nodes axis
    // (the acceptance criterion for the Fig 5-6 reproduction).
    let sizes: Vec<String> = fig5
        .records
        .iter()
        .map(|r| r.axes[1].1.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for size in &sizes {
        let curve: Vec<(usize, f64)> = fig5
            .records
            .iter()
            .filter(|r| &r.axes[1].1 == size)
            .map(|r| (r.nodes, r.makespan_secs))
            .collect();
        for w in curve.windows(2) {
            assert!(
                w[0].0 < w[1].0,
                "fig5 records must arrive in grid order ({:?})",
                curve
            );
            assert!(
                w[1].1 <= w[0].1 * (1.0 + 1e-9),
                "fig5 {size}: makespan must not grow with nodes — \
                 {} nodes {:.1} s vs {} nodes {:.1} s",
                w[0].0,
                w[0].1,
                w[1].0,
                w[1].1
            );
        }
    }

    // ---- Sphere-over-Hadoop WAN speedup surface ----
    let wan_spec = SweepSpec::speedup_wan();
    let (wan, h_wan) = run_preset("speedup_wan", &wan_spec, &mut json);
    assert_eq!(wan.records.len(), 12, "wan grid is 3 node counts x 4 WAN capacities");
    let (mut s_lo, mut s_hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for r in &wan.records {
        let s = r.speedup.expect("every surface point ran both engines");
        assert!(
            s > 1.0,
            "the paper's headline must hold at every grid point — Sphere beats \
             Hadoop (point #{} got {s:.2}x)",
            r.index
        );
        s_lo = s_lo.min(s);
        s_hi = s_hi.max(s);
    }
    json.num("speedup_wan_min_speedup", s_lo).num("speedup_wan_max_speedup", s_hi);

    let hash = format!("{:016x}-{:016x}", h_fig5, h_wan);
    json.text("determinism_hash", &hash);

    // ---- regression gate against the committed baseline ----
    // Read the committed file BEFORE overwriting it, and write the new
    // numbers BEFORE any drift panic — the CI artifact must carry the
    // new values even when the gate trips.
    let committed = std::fs::read_to_string(baseline_path());
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_sweep.json not written: {e}"),
    }
    let update = std::env::var("BENCH_SWEEP_UPDATE").is_ok();
    match committed {
        Ok(committed) => {
            let base_hash = field(&committed, "determinism_hash").unwrap_or(UNSET);
            if base_hash == UNSET {
                println!(
                    "baseline is a bootstrap placeholder: commit the rewritten \
                     BENCH_sweep.json to arm the drift gate \
                     (README 'Calibration & baselines')"
                );
            } else if update {
                println!("BENCH_SWEEP_UPDATE set: accepting new baseline {hash}");
            } else {
                let mut drift = Vec::new();
                if base_hash != hash {
                    drift.push(format!("determinism hash {base_hash} -> {hash}"));
                }
                for key in ["fig5_scaling_points", "speedup_wan_points"] {
                    let old = field(&committed, key).unwrap_or("?");
                    let new_json = json.render();
                    let new = field(&new_json, key).unwrap_or("?");
                    if old != new {
                        drift.push(format!("{key} {old} -> {new}"));
                    }
                }
                if !drift.is_empty() {
                    for d in &drift {
                        eprintln!("DRIFT: {d}");
                    }
                    panic!(
                        "bench_sweep drifted from the committed baseline — if \
                         intentional, rerun with BENCH_SWEEP_UPDATE=1 and commit \
                         the rewritten BENCH_sweep.json"
                    );
                }
                println!("baseline check: point counts and determinism hash match");
            }
        }
        Err(_) => println!("no committed baseline found; wrote a fresh one"),
    }
}

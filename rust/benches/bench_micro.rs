//! Hot-path microbenchmarks (the §Perf working set): segmentation,
//! scheduler assignment, shuffle bucketing, record sort, Chord lookup,
//! netsim event loop, GMP codec.  Used before/after every optimization
//! (experiment index: DESIGN.md §5).
//!
//!     cargo bench --bench bench_micro

use sector_sphere::bench::{black_box, print_timing, time_fn};
use sector_sphere::mining::terasort::{generate_records, key_bucket, RECORD_BYTES};
use sector_sphere::routing::chord::ChordRing;
use sector_sphere::sector::RecordIndex;
use sector_sphere::sim::netsim::NetSim;
use sector_sphere::sphere::{segment_stream, Scheduler, Stream, StreamFile};
use sector_sphere::transport::gmp::{decode, encode, Datagram, DatagramKind};
use sector_sphere::util::rng::Pcg64;

fn main() {
    println!("=== hot-path microbenches ===");

    // --- segmentation: 64 files x 10k records ---
    let stream = Stream {
        files: (0..64)
            .map(|i| StreamFile {
                name: format!("f{i:03}.dat"),
                size_bytes: 1_000_000,
                n_records: 10_000,
                locations: vec![i % 8],
            })
            .collect(),
    };
    let idx = RecordIndex::fixed(100, 1_000_000);
    let t = time_fn("segment_stream 64x10k records", 3, 20, || {
        segment_stream(&stream, 8, 64_000, 256_000, |_| Some(idx.clone()))
    });
    print_timing(&t);

    // --- scheduler: assign/complete 1024 segments over 8 nodes ---
    let segs = segment_stream(&stream, 8, 32_000, 64_000, |_| Some(idx.clone()));
    println!("  ({} segments)", segs.len());
    let t = time_fn("scheduler drain (locality on)", 3, 20, || {
        let mut sched = Scheduler::new(segs.clone(), true);
        let mut done = 0;
        while let Some(s) = sched.assign((done % 8) as u32) {
            sched.complete(&s);
            done += 1;
        }
        done
    });
    print_timing(&t);

    // --- bucket partitioning: 100k records ---
    let data = generate_records(100_000, 1);
    let t = time_fn("key_bucket over 100k records", 3, 20, || {
        let mut acc = 0u64;
        for rec in data.chunks_exact(RECORD_BYTES) {
            acc += key_bucket(&rec[..10], 64) as u64;
        }
        acc
    });
    print_timing(&t);

    // --- record sort: 100k records by 10-byte key ---
    let t = time_fn("sort 100k records by key (memcmp)", 1, 10, || {
        let mut recs: Vec<&[u8]> = data.chunks_exact(RECORD_BYTES).collect();
        recs.sort_by(|a, b| a[..10].cmp(&b[..10]));
        recs.len()
    });
    print_timing(&t);
    // the optimized TeraSortOp path: precomputed u128 keys + unstable sort
    let t = time_fn("sort 100k records by key (u128 keyed)", 1, 10, || {
        let mut keyed: Vec<(u128, &[u8])> = data
            .chunks_exact(RECORD_BYTES)
            .map(|r| {
                let mut k = [0u8; 16];
                k[..10].copy_from_slice(&r[..10]);
                (u128::from_be_bytes(k), r)
            })
            .collect();
        keyed.sort_unstable_by_key(|(k, _)| *k);
        keyed.len()
    });
    print_timing(&t);

    // --- chord lookup: 256-node ring ---
    let mut rng = Pcg64::new(5);
    let ids: Vec<u64> = (0..256).map(|_| rng.next_u64()).collect();
    let ring = ChordRing::build(&ids);
    let keys: Vec<u64> = (0..1000).map(|_| rng.next_u64()).collect();
    let t = time_fn("chord lookup x1000 (256 nodes)", 3, 20, || {
        let mut hops = 0u32;
        for &k in &keys {
            hops += ring.lookup(ids[0], k).unwrap().1;
        }
        hops
    });
    print_timing(&t);

    // --- netsim: 8-node all-to-all flow completion ---
    let t = time_fn("netsim 56-flow all-to-all to idle", 3, 20, || {
        let mut net = NetSim::new();
        let links: Vec<_> = (0..16).map(|_| net.add_link(1e9)).collect();
        for i in 0..8usize {
            for j in 0..8usize {
                if i != j {
                    net.start_flow(&[links[i], links[8 + j]], 1e8, 5e8);
                }
            }
        }
        net.run_to_idle()
    });
    print_timing(&t);

    // --- GMP codec ---
    let d = Datagram {
        src: 1,
        dst: 2,
        seq: 42,
        kind: DatagramKind::Msg,
        payload: vec![7u8; 256],
    };
    let t = time_fn("gmp encode+decode x1000", 3, 20, || {
        for _ in 0..1000 {
            let bytes = encode(black_box(&d));
            black_box(decode(&bytes).unwrap());
        }
    });
    print_timing(&t);

    println!("micro OK");
}

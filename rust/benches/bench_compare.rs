//! Head-to-head gate (DESIGN.md §12): run the two compare presets —
//! the paper's 4-node WAN Terasort and the 128-node faulted scale-out
//! — through BOTH engines, twice each for the determinism contract,
//! then check the Sphere/Hadoop speedup ratio and the determinism hash
//! against the committed baseline in `BENCH_compare.json` at the repo
//! root.  Any drift fails the bench (and therefore CI's
//! bench-trajectory job); an intentional recalibration re-runs with
//! `BENCH_COMPARE_UPDATE=1` and commits the rewritten JSON.
//!
//!     cargo bench --bench bench_compare
//!
//! The emitted JSON carries ONLY deterministic simulation outputs (no
//! wall clock), so the file is byte-stable across runs of one build:
//! per-preset makespans for both systems, speedups, per-tier WAN
//! bytes, speculation counters, and an FNV hash of each serialized
//! report.  Wall-clock timings are printed to stdout instead.

use sector_sphere::bench::{time_fn, BenchJson};
use sector_sphere::routing::hash_name;
use sector_sphere::scenario::{run_scenario, ScenarioReport, ScenarioSpec};

/// Marker a bootstrap baseline carries before the first real run.
const UNSET: &str = "UNSET";

fn baseline_path() -> std::path::PathBuf {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("."));
    base.join("BENCH_compare.json")
}

/// Pull `"key": value` out of the flat baseline JSON without serde.
fn field<'a>(json: &'a str, key: &str) -> Option<&'a str> {
    let tag = format!("\"{key}\": ");
    let start = json.find(&tag)? + tag.len();
    let rest = &json[start..];
    let end = rest.find(&[',', '}'][..])?;
    Some(rest[..end].trim().trim_matches('"'))
}

fn run_preset(name: &str, spec: &ScenarioSpec, json: &mut BenchJson) -> (ScenarioReport, u64) {
    let a = run_scenario(spec).unwrap_or_else(|e| panic!("{name}: {e}"));
    let b = run_scenario(spec).unwrap_or_else(|e| panic!("{name} rerun: {e}"));
    assert_eq!(
        format!("{a:?}"),
        format!("{b:?}"),
        "{name}: serialized reports must be byte-identical"
    );
    let hash = hash_name(&format!("{a:?}"));
    let t = time_fn(name, 1, 3, || run_scenario(spec).unwrap());
    let cmp = a.comparison.clone().expect("compare preset reports both systems");
    println!(
        "{name}: sphere {:.1} s vs hadoop {:.1} s -> speedup {:.2}x ({:.0} ms wall)",
        cmp.sphere.makespan_secs,
        cmp.hadoop.makespan_secs,
        cmp.speedup,
        t.secs.mean * 1e3
    );
    for s in [&cmp.sphere, &cmp.hadoop] {
        println!(
            "  {:<7} tasks {:>5}  local {:>3.0}%  nic {:>7.2} GB  rack {:>7.2} GB  \
             wan {:>7.2} GB  spec {}/{}",
            s.system,
            s.tasks,
            s.locality_fraction * 100.0,
            s.tier.nic / 1e9,
            s.tier.rack / 1e9,
            s.tier.wan / 1e9,
            s.speculative_won,
            s.speculative_launched,
        );
    }
    assert!(
        cmp.speedup > 1.0,
        "{name}: the paper's headline must hold — Sphere beats Hadoop \
         (got {:.2}x)",
        cmp.speedup
    );
    json.num(&format!("{name}_sphere_makespan_secs"), cmp.sphere.makespan_secs)
        .num(&format!("{name}_hadoop_makespan_secs"), cmp.hadoop.makespan_secs)
        .num(&format!("{name}_speedup"), cmp.speedup)
        .num(&format!("{name}_sphere_wan_gbytes"), cmp.sphere.tier.wan / 1e9)
        .num(&format!("{name}_hadoop_wan_gbytes"), cmp.hadoop.tier.wan / 1e9)
        .int(&format!("{name}_hadoop_spec_launched"), cmp.hadoop.speculative_launched)
        .int(&format!("{name}_hadoop_spec_won"), cmp.hadoop.speculative_won)
        .int(&format!("{name}_events"), a.events);
    (a, hash)
}

fn main() {
    let mut json = BenchJson::new("compare");
    json.text("bench", "compare");

    let (_, h_wan4) = run_preset("compare_wan4", &ScenarioSpec::compare_wan4(), &mut json);
    let (s128, h_s128) =
        run_preset("compare_scale128", &ScenarioSpec::compare_scale128(), &mut json);
    assert_eq!(s128.nodes_crashed, 1, "the scale128 fault plan fired");
    assert!(
        s128.comparison.as_ref().unwrap().hadoop.speculative_launched > 0,
        "the 2x straggler must trip Hadoop's speculation rule"
    );

    let hash = format!("{:016x}-{:016x}", h_wan4, h_s128);
    json.text("determinism_hash", &hash);

    // ---- regression gate against the committed baseline ----
    // Read the committed file BEFORE overwriting it, and write the new
    // numbers BEFORE any drift panic — the CI artifact must carry the
    // new values even when the gate trips, or the failure is only
    // diagnosable from the job log.
    let committed = std::fs::read_to_string(baseline_path());
    match json.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("BENCH_compare.json not written: {e}"),
    }
    let update = std::env::var("BENCH_COMPARE_UPDATE").is_ok();
    match committed {
        Ok(committed) => {
            let base_hash = field(&committed, "determinism_hash").unwrap_or(UNSET);
            if base_hash == UNSET {
                println!(
                    "baseline is a bootstrap placeholder: commit the rewritten \
                     BENCH_compare.json to arm the drift gate"
                );
            } else if update {
                println!("BENCH_COMPARE_UPDATE set: accepting new baseline {hash}");
            } else {
                let mut drift = Vec::new();
                if base_hash != hash {
                    drift.push(format!("determinism hash {base_hash} -> {hash}"));
                }
                for key in ["compare_wan4_speedup", "compare_scale128_speedup"] {
                    let old: f64 = field(&committed, key)
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(f64::NAN);
                    let new: f64 = field(&json.render(), key)
                        .and_then(|v| v.parse().ok())
                        .unwrap_or(f64::NAN);
                    if !(old.is_finite() && (old - new).abs() <= 1e-9 * old.abs().max(1.0)) {
                        drift.push(format!("{key} {old} -> {new}"));
                    }
                }
                if !drift.is_empty() {
                    for d in &drift {
                        eprintln!("DRIFT: {d}");
                    }
                    panic!(
                        "bench_compare drifted from the committed baseline — if \
                         intentional, rerun with BENCH_COMPARE_UPDATE=1 and commit \
                         the rewritten BENCH_compare.json"
                    );
                }
                println!("baseline check: speedups and determinism hash match");
            }
        }
        Err(_) => println!("no committed baseline found; wrote a fresh one"),
    }
}

//! The Angle application (paper §7) end to end: four synthetic sensor
//! sites produce anonymized packet windows with a planted port-scan
//! regime shift; Sector stores the pcap files; a Sphere UDF extracts
//! per-source features; the client clusters each temporal window
//! through the PJRT k-means artifact, computes the delta_j series
//! (Figs 5-6), flags the emergent window, and scores sources with
//! rho(x).
//!
//!     cargo run --release --offline --example angle_pipeline
//!     # optional PJRT path: make artifacts + a `--features pjrt` build

use sector_sphere::cluster::Cluster;
use sector_sphere::mining::{run_pipeline, AngleScenario, Regime};
use sector_sphere::util::hist::ascii_plot;

fn main() -> Result<(), String> {
    // Prefer the PJRT k-means artifact, fall back to the host oracles
    // (identical models either way; DESIGN.md §8).
    let builder = || Cluster::builder().nodes(4).seed(20080824);
    let cluster = match builder().with_runtime(true).build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("note: PJRT unavailable, using host oracles ({e})");
            builder().build()?
        }
    };
    let scenario = AngleScenario {
        sensors: 4,
        sources_per_sensor: 25,
        windows: 10,
        packets_per_source: 40,
        anomalies: vec![(6, 3, Regime::Scan), (6, 11, Regime::Exfil)],
        seed: 20080824,
        k: 6,
    };
    println!(
        "angle: {} sensors x {} sources x {} windows (scan+exfil planted at window 6)",
        scenario.sensors, scenario.sources_per_sensor, scenario.windows
    );

    let report = run_pipeline(&cluster.cloud, &scenario, cluster.runtime.as_ref())?;

    println!(
        "  {} pcap files -> {} feature vectors",
        report.feature_files, report.features_total
    );
    println!("\ndelta_j series (cluster movement between windows, cf. Fig 5):");
    print!("{}", ascii_plot(&report.analysis.deltas, 60, 8));
    println!("  deltas: {:?}", report
        .analysis
        .deltas
        .iter()
        .map(|d| (d * 100.0).round() / 100.0)
        .collect::<Vec<_>>());
    println!("  emergent windows flagged: {:?}", report.emergent_window_ids);
    println!("  emergent clusters: {}", report.clusters.len());
    println!("\ntop scored sources (rho, paper §7.1):");
    for (src, w, score) in &report.top_scores {
        println!("  rho={score:.4}  src={src:016x}  window={w}");
    }

    assert!(
        report.emergent_window_ids.contains(&6),
        "planted regime shift must be flagged: {:?}",
        report.emergent_window_ids
    );
    assert!(!report.clusters.is_empty());
    println!("\nangle_pipeline OK");
    Ok(())
}

//! Paper-scale simulation from the command line: reproduce any Table 1
//! (WAN) or Table 2 (LAN) column — Sphere vs Hadoop, Terasort +
//! Terasplit at 10 GB/node — on the simulated testbeds.
//!
//!     cargo run --release --offline --example wan_sim

use sector_sphere::config::SimConfig;
use sector_sphere::hadoop::simulate_hadoop_row;
use sector_sphere::sphere::simjob::simulate_sphere_row;
use sector_sphere::topology::Testbed;
use sector_sphere::util::bytes::GB;

fn main() {
    let bytes = 10.0 * GB as f64;

    println!("WAN testbed (2x Chicago, 2x Pasadena, 2x Greenbelt; 10 Gb/s; Table 1):");
    println!(
        "  {:<6} {:>6} {:>14} {:>14} {:>14} {:>14} {:>8}",
        "nodes", "sites", "sphere sort", "hadoop sort", "sphere split", "hadoop split", "speedup"
    );
    for n in 1..=6 {
        let t = Testbed::wan_testbed(n);
        let cfg = SimConfig::wan_default();
        let s = simulate_sphere_row(&t, &cfg, bytes);
        let h = simulate_hadoop_row(&t, &cfg, bytes);
        let speedup = (h.terasort_secs + h.terasplit_secs)
            / (s.terasort_secs + s.terasplit_secs);
        println!(
            "  {:<6} {:>6} {:>14.0} {:>14.0} {:>14.0} {:>14.0} {:>8.1}",
            n,
            t.sites_used(),
            s.terasort_secs,
            h.terasort_secs,
            s.terasplit_secs,
            h.terasplit_secs,
            speedup
        );
    }

    println!("\nLAN testbed (8-node rack; Table 2):");
    println!(
        "  {:<6} {:>14} {:>14} {:>14} {:>14} {:>8}",
        "nodes", "sphere sort", "hadoop sort", "sphere split", "hadoop split", "speedup"
    );
    for n in 1..=8 {
        let t = Testbed::lan_testbed(n);
        let cfg = SimConfig::lan_default();
        let s = simulate_sphere_row(&t, &cfg, bytes);
        let h = simulate_hadoop_row(&t, &cfg, bytes);
        let speedup = (h.terasort_secs + h.terasplit_secs)
            / (s.terasort_secs + s.terasplit_secs);
        println!(
            "  {:<6} {:>14.0} {:>14.0} {:>14.0} {:>14.0} {:>8.1}",
            n, s.terasort_secs, h.terasort_secs, s.terasplit_secs, h.terasplit_secs, speedup
        );
    }
    println!("\n(cargo bench --bench bench_table1/2 prints the paper-vs-measured checks)");
}

//! Fold every per-bench `BENCH_*.json` at the repo root into one
//! `BENCH_trajectory.json` aggregate — the single artifact CI's
//! bench-trajectory and nightly jobs upload, so the perf trajectory
//! across PRs is one file per run instead of a loose pile of
//! per-bench emissions.
//!
//!     cargo run --release --example bench_trajectory
//!
//! No dependencies and no serde: each per-bench file is embedded
//! verbatim (they are trusted single-object emissions from
//! `BenchJson`), keyed by bench name in sorted order so the aggregate
//! is deterministic for a given set of inputs.  Benches whose
//! committed baseline still carries the `"UNSET"` bootstrap marker
//! are listed under `"unarmed"` — a reviewer can see at a glance
//! which drift gates are live.

use std::fs;
use std::path::PathBuf;
use std::process::ExitCode;

const OUT: &str = "BENCH_trajectory.json";

fn main() -> ExitCode {
    let root = std::env::var("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."));
    let mut reports: Vec<(String, String)> = Vec::new();
    let dir = match fs::read_dir(&root) {
        Ok(d) => d,
        Err(e) => {
            eprintln!("cannot read {}: {e}", root.display());
            return ExitCode::FAILURE;
        }
    };
    for entry in dir.flatten() {
        let name = entry.file_name().to_string_lossy().into_owned();
        if !name.starts_with("BENCH_") || !name.ends_with(".json") || name == OUT {
            continue;
        }
        let bench = name["BENCH_".len()..name.len() - ".json".len()].to_string();
        match fs::read_to_string(entry.path()) {
            Ok(body) => {
                let body = body.trim().to_string();
                // Only well-formed single-object emissions embed raw;
                // anything else would corrupt the aggregate.
                if body.starts_with('{') && body.ends_with('}') {
                    reports.push((bench, body));
                } else {
                    eprintln!("skipping {name}: not a JSON object");
                }
            }
            Err(e) => eprintln!("skipping {name}: {e}"),
        }
    }
    if reports.is_empty() {
        eprintln!("no BENCH_*.json found at {} — run the benches first", root.display());
        return ExitCode::FAILURE;
    }
    reports.sort();

    let benches: Vec<String> = reports.iter().map(|(b, _)| format!("\"{b}\"")).collect();
    let unarmed: Vec<String> = reports
        .iter()
        .filter(|(_, body)| body.contains("\"determinism_hash\": \"UNSET\""))
        .map(|(b, _)| format!("\"{b}\""))
        .collect();
    let embedded: Vec<String> = reports
        .iter()
        .map(|(b, body)| format!("    \"{b}\": {body}"))
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"trajectory\",\n  \"count\": {},\n  \
         \"benches\": [{}],\n  \"unarmed\": [{}],\n  \"reports\": {{\n{}\n  }}\n}}\n",
        reports.len(),
        benches.join(", "),
        unarmed.join(", "),
        embedded.join(",\n"),
    );
    let out = root.join(OUT);
    if let Err(e) = fs::write(&out, &json) {
        eprintln!("cannot write {}: {e}", out.display());
        return ExitCode::FAILURE;
    }
    println!(
        "folded {} bench reports into {} ({} drift gate(s) still unarmed)",
        reports.len(),
        out.display(),
        unarmed.len()
    );
    for (b, _) in &reports {
        let armed = if unarmed.contains(&format!("\"{b}\"")) {
            "unarmed (bootstrap placeholder)"
        } else {
            "armed"
        };
        println!("  {b:<12} {armed}");
    }
    ExitCode::SUCCESS
}

//! END-TO-END DRIVER: the full system on a real small workload,
//! proving all layers compose (architecture: DESIGN.md §1).
//!
//! A 4-node disk-backed Sector cloud sorts 40 MB of real gensort
//! records through the two-stage Sphere Terasort (range-partition +
//! shuffle over the cloud, then per-bucket local sorts), validates
//! global key order, and computes the Terasplit entropy split through
//! the AOT-compiled PJRT artifact (L1 Pallas scan inside) when one is
//! available — the host oracle otherwise (identical results,
//! DESIGN.md §8).
//!
//!     cargo run --release --offline --example terasort_e2e
//!     # optional PJRT path: make artifacts + a `--features pjrt` build

use sector_sphere::cluster::Cluster;
use sector_sphere::util::bytes::{fmt_bytes, fmt_rate_bytes_per_sec};

fn main() -> Result<(), String> {
    let nodes = 4;
    let records_per_node = 100_000; // 10 MB/node, 40 MB total
    let builder = || {
        Cluster::builder()
            .nodes(nodes)
            .seed(20080824)
            .on_disk(true) // real files under a temp dir
    };
    // Prefer the PJRT artifacts, fall back to the host oracles (same
    // answers either way; the artifacts prove the AOT path).
    let cluster = match builder().with_runtime(true).build() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("note: PJRT unavailable, using host oracles ({e})");
            builder().build()?
        }
    };
    println!(
        "terasort e2e: {nodes} disk-backed nodes x {records_per_node} records \
         ({} total), split via {}",
        fmt_bytes((nodes * records_per_node * 100) as u64),
        if cluster.runtime.is_some() { "PJRT artifact" } else { "host oracle" },
    );

    let report = cluster.terasort_e2e(records_per_node)?;

    let total_bytes = (report.records * 100) as f64;
    println!("  records sorted      {}", report.records);
    println!("  bucket files        {}", report.bucket_files);
    println!("  sorted output files {}", report.sorted_files.len());
    println!("  globally sorted     {}", report.globally_sorted);
    println!(
        "  terasplit           gain {:.4} bits at record {}",
        report.split_gain_bits, report.split_index
    );
    println!(
        "  partition locality  {:.0}%",
        report.partition_locality * 100.0
    );
    println!(
        "  wall time           {:.2} s  ({} through the full stack)",
        report.wall_secs,
        fmt_rate_bytes_per_sec(total_bytes / report.wall_secs)
    );
    println!("\nmetrics:\n{}", cluster.cloud.metrics.report());

    assert!(report.globally_sorted, "global sort order must hold");
    assert_eq!(report.records, nodes * records_per_node, "no record loss");
    assert!(report.split_gain_bits >= 0.0);
    println!("terasort_e2e OK");
    Ok(())
}

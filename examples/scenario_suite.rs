//! Scenario suite: run the TOML-described scenarios under
//! config/scenarios/ end to end — the two paper testbeds plus the
//! 128-node faulted scale-out — and assert that every run is
//! deterministic (same spec, byte-identical report; DESIGN.md §4).
//!
//!     cargo run --release --example scenario_suite

use std::path::PathBuf;

use sector_sphere::scenario::{run_scenario, ScenarioSpec};

/// Load a scenario TOML from config/scenarios/, falling back to the
/// equivalent built-in preset when the file is not reachable (e.g. an
/// installed binary running outside the repo).
fn load_or(preset: ScenarioSpec, file: &str) -> ScenarioSpec {
    let base = std::env::var("CARGO_MANIFEST_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("."));
    let path = base.join("config/scenarios").join(file);
    match std::fs::read_to_string(&path) {
        Ok(text) => ScenarioSpec::from_toml(&text)
            .unwrap_or_else(|e| panic!("{}: {e}", path.display())),
        Err(_) => preset,
    }
}

fn main() {
    let specs = [
        load_or(ScenarioSpec::paper_wan6(), "paper_wan6.toml"),
        load_or(ScenarioSpec::paper_lan8(), "paper_lan8.toml"),
        load_or(ScenarioSpec::scale128(), "scale128.toml"),
        load_or(ScenarioSpec::traffic_scale128(), "traffic_scale128.toml"),
        load_or(ScenarioSpec::traffic_elastic512(), "traffic_elastic512.toml"),
        load_or(ScenarioSpec::colocate_scale128(), "colocate_scale128.toml"),
        load_or(ScenarioSpec::compare_wan4(), "compare_wan4.toml"),
        load_or(ScenarioSpec::compare_scale128(), "compare_scale128.toml"),
        load_or(ScenarioSpec::angle_wan4(), "angle_wan4.toml"),
        load_or(ScenarioSpec::angle_scale128(), "angle_scale128.toml"),
        load_or(ScenarioSpec::churn_wan32(), "churn_wan32.toml"),
        load_or(ScenarioSpec::weather_compare16(), "weather_compare16.toml"),
    ];
    println!(
        "{:<28} {:>6} {:>6} {:>12} {:>9} {:>9} {:>7} {:>7}",
        "scenario", "nodes", "racks", "makespan(s)", "events", "segments", "local%", "faults"
    );
    for spec in &specs {
        let a = run_scenario(spec).expect("scenario runs");
        let b = run_scenario(spec).expect("scenario reruns");
        assert_eq!(a, b, "{}: same spec must give the same report", spec.name);
        println!(
            "{:<28} {:>6} {:>6} {:>12.1} {:>9} {:>9} {:>6.0}% {:>7}",
            a.name,
            a.nodes,
            a.racks,
            a.makespan_secs,
            a.events,
            a.segments,
            a.locality_fraction * 100.0,
            a.faults_injected
        );
        if let Some(t) = &a.traffic {
            for slo in &t.tenants {
                println!(
                    "  `- {:<12} p50 {:>8.1} ms  p95 {:>8.1} ms  p99 {:>8.1} ms  \
                     {:>6} done {:>5} rej",
                    slo.name, slo.p50_ms, slo.p95_ms, slo.p99_ms, slo.completed, slo.rejected
                );
            }
        }
        if let Some(e) = &a.elasticity {
            println!(
                "  `- {} scaler: {} grows / {} sheds, {:.2} GB re-replicated, \
                 peak {} replicas, {} violations",
                e.policy,
                e.grows,
                e.sheds,
                e.rereplication.total() / 1e9,
                e.peak_replicas,
                e.invariant_violations
            );
            assert_eq!(
                e.invariant_violations, 0,
                "{}: replica invariants must hold",
                a.name
            );
        }
        if let Some(co) = &a.colocation {
            println!(
                "  `- job done in {:>8.1} s; speculation {} launched / {} won",
                co.job_makespan_secs, a.speculative_launched, a.speculative_won
            );
        }
        if let Some(an) = &a.angle {
            println!(
                "  `- angle {} windows / {} files: recall {:.2} \
                 (found {:?}), models {:.1} KB cross-tier, spec {}/{}",
                an.windows,
                an.files,
                an.recall,
                an.emergent_found,
                an.model_tier.total() / 1e3,
                a.speculative_won,
                a.speculative_launched,
            );
            assert_eq!(an.recall, 1.0, "{}: planted shifts must be found", a.name);
        }
        if let Some(cmp) = &a.comparison {
            println!(
                "  `- sphere {:>8.1} s vs hadoop {:>8.1} s -> speedup {:.2}x \
                 (hadoop wan {:.2} GB, spec {}/{})",
                cmp.sphere.makespan_secs,
                cmp.hadoop.makespan_secs,
                cmp.speedup,
                cmp.hadoop.tier.wan / 1e9,
                cmp.hadoop.speculative_won,
                cmp.hadoop.speculative_launched,
            );
        }
    }
    println!("\nall scenarios completed; each ran twice with byte-identical reports");
}

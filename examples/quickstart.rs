//! Quickstart: bring up a 4-node in-process Sector cloud, upload two
//! record-indexed files, replicate them, and run a grep-style Sphere
//! UDF — the paper's `sphere.run(sdss, "findBrownDwarf")` shape.
//!
//!     cargo run --release --offline --example quickstart

use sector_sphere::sector::{RecordIndex, ReplicationManager, SectorCloud};
use sector_sphere::sphere::{run_job, FaultPlan, GrepOp, JobSpec, Stream};

fn main() -> Result<(), String> {
    // 1. A 4-node cloud with replica target 2 and a write ACL.
    let cloud = SectorCloud::builder()
        .nodes(4)
        .replicas(2)
        .allow_writers(&["10.0.0.0/8"])
        .seed(1)
        .build()?;
    let client_ip = "10.0.0.99".parse().unwrap();

    // 2. Upload line-record files with companion .idx indexes (paper §4).
    for (i, text) in [
        "candidate: brown dwarf 0957\nstar: blue giant 0021\n",
        "galaxy: spiral 1189\ncandidate: brown dwarf 1200\n",
        "star: red dwarf 0440\nnebula: crab\n",
    ]
    .iter()
    .enumerate()
    {
        let lengths: Vec<u64> = text.split_inclusive('\n').map(|l| l.len() as u64).collect();
        let idx = RecordIndex::from_lengths(&lengths);
        let name = format!("sdss{}.dat", i + 1);
        let node = cloud.upload(client_ip, &name, text.as_bytes(), Some(&idx), None)?;
        println!("uploaded {name} -> slave {node} ({} records)", lengths.len());
    }

    // 3. Replication check (the paper runs this daily).
    let mut mgr = ReplicationManager::new(86_400.0);
    let created = mgr.check_all(&cloud);
    println!("replication: created {created} replicas (target 2)");

    // 4. Locate through the Chord routing layer.
    let (locations, hops) = cloud.locate(0, "sdss1.dat");
    println!("locate sdss1.dat -> slaves {locations:?} in {hops} chord hops");

    // 5. sphere.run(stream, grep "brown dwarf").
    let stream = Stream::from_cloud(
        &cloud,
        &["sdss1.dat".into(), "sdss2.dat".into(), "sdss3.dat".into()],
    )?;
    let result = run_job(
        &cloud,
        &GrepOp,
        &stream,
        &JobSpec {
            params: b"brown dwarf".to_vec(),
            seg_min_bytes: 1,
            seg_max_bytes: 4096,
            ..JobSpec::default()
        },
        &FaultPlan::default(),
    )?;
    println!(
        "sphere job: {} segments, locality {:.0}%",
        result.segments_total,
        result.locality_fraction * 100.0
    );
    println!("matches:");
    for (_, rec) in &result.to_client {
        print!("  {}", String::from_utf8_lossy(rec));
    }
    assert_eq!(result.to_client.len(), 2, "two brown-dwarf candidates");

    println!("\nmetrics:\n{}", cloud.metrics.report());
    println!("quickstart OK");
    Ok(())
}

"""L2 model semantics + AOT round-trip checks."""
from __future__ import annotations

import numpy as np
import pytest
from numpy.testing import assert_allclose

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref


def _pad_ids(ids, n):
    out = np.zeros(n, np.float32)
    out[: len(ids)] = ids
    valid = np.zeros(n, np.float32)
    valid[: len(ids)] = 1.0
    return jnp.asarray(out), jnp.asarray(valid)


def test_split_gain_matches_ref_onehot():
    rng = np.random.default_rng(0)
    ids = np.sort(rng.integers(0, model.N_CLASSES, size=10_000))
    class_ids, valid = _pad_ids(ids, model.N_LABELS)
    g, i = model.split_gain(class_ids, valid)
    onehot = np.zeros((model.N_LABELS, model.N_CLASSES), np.float32)
    onehot[np.arange(10_000), ids] = 1.0
    g_ref, _ = ref.split_scan_ref(jnp.asarray(onehot), valid)
    assert_allclose(float(g), float(g_ref), rtol=1e-4, atol=1e-5)
    assert 0 <= int(i) < 10_000


def test_kmeans_step_full_artifact_shape():
    rng = np.random.default_rng(1)
    pts = jnp.asarray(rng.normal(size=(model.N_POINTS, model.N_DIM)).astype(np.float32))
    ctr = jnp.asarray(rng.normal(size=(model.N_CLUSTERS, model.N_DIM)).astype(np.float32))
    w = jnp.ones(model.N_POINTS, jnp.float32)
    sums, counts, inertia = model.kmeans_step(pts, ctr, w)
    assert sums.shape == (model.N_CLUSTERS, model.N_DIM)
    assert counts.shape == (model.N_CLUSTERS,)
    assert float(jnp.sum(counts)) == pytest.approx(model.N_POINTS)
    want = ref.kmeans_step_ref(pts, ctr, w)
    assert_allclose(np.asarray(sums), np.asarray(want[0]), rtol=3e-5, atol=3e-5)
    assert_allclose(float(inertia), float(want[2]), rtol=1e-5)


def test_delta_and_score_shapes():
    rng = np.random.default_rng(2)
    K, D, B = model.N_CLUSTERS, model.N_DIM, model.N_SCORE_BATCH
    ca = jnp.asarray(rng.normal(size=(K, D)).astype(np.float32))
    cb = jnp.asarray(rng.normal(size=(K, D)).astype(np.float32))
    live = jnp.ones(K, jnp.float32)
    d = model.delta_stat(ca, cb, live, live)
    assert d.shape == ()
    assert float(d) >= 0
    x = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32))
    ones = jnp.ones(K, jnp.float32)
    r = model.score(x, ca, ones, ones, ones, live)
    assert r.shape == (B,)
    assert np.all(np.asarray(r) >= 0)


@pytest.mark.parametrize("name", sorted(model.ARTIFACTS))
def test_aot_lowering_produces_parseable_hlo(name, tmp_path):
    fn, example_args = model.ARTIFACTS[name]
    lowered = jax.jit(fn).lower(*example_args)
    text = aot.to_hlo_text(lowered)
    # Sanity: an HLO module with an ENTRY computation and a tuple root.
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    # jax >= 0.5 proto ids overflow xla_extension 0.5.1; text avoids that.
    assert "custom-call" not in text.lower() or "Mosaic" not in text, (
        "Mosaic custom-call leaked into the artifact: a kernel was lowered "
        "without interpret=True and cannot run on the CPU PJRT client"
    )


def test_lower_all_writes_manifest(tmp_path):
    manifest = aot.lower_all(str(tmp_path))
    assert set(manifest) == set(model.ARTIFACTS)
    listing = (tmp_path / "MANIFEST.txt").read_text().strip().splitlines()
    assert len(listing) == len(model.ARTIFACTS)
    for name in model.ARTIFACTS:
        assert (tmp_path / f"{name}.hlo.txt").exists()

"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

hypothesis sweeps shapes/seeds; numpy.testing.assert_allclose is the
equality judge.  Everything runs under interpret=True on CPU.
"""
from __future__ import annotations

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

# NB: compile.kernels.__init__ re-exports the kernel *functions*, which
# shadows the submodule names in the package namespace ("import x.y as z"
# prefers the attribute); importlib bypasses the shadowing.
import importlib

kmeans = importlib.import_module("compile.kernels.kmeans")
ref = importlib.import_module("compile.kernels.ref")
split_scan = importlib.import_module("compile.kernels.split_scan")

RNG = np.random.default_rng


# ----------------------------------------------------------------- kmeans

def _kmeans_case(n, d, k, seed, pad_frac=0.0):
    rng = RNG(seed)
    pts = rng.normal(size=(n, d)).astype(np.float32)
    ctr = rng.normal(size=(k, d)).astype(np.float32)
    w = np.ones(n, np.float32)
    n_pad = int(n * pad_frac)
    if n_pad:
        w[-n_pad:] = 0.0
        pts[-n_pad:] = 1e6  # poison padding rows: must not leak into outputs
    return jnp.asarray(pts), jnp.asarray(ctr), jnp.asarray(w)


@pytest.mark.parametrize("n,d,k,tile", [
    (512, 16, 32, 512),
    (1024, 16, 32, 512),
    (4096, 16, 32, 512),
    (2048, 8, 4, 256),
    (256, 2, 2, 128),
])
def test_kmeans_matches_ref(n, d, k, tile):
    pts, ctr, w = _kmeans_case(n, d, k, seed=n + d + k)
    got = kmeans.kmeans_step(pts, ctr, w, tile_n=tile)
    want = ref.kmeans_step_ref(pts, ctr, w)
    for g, r in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(r), rtol=2e-5, atol=2e-5)


def test_kmeans_padding_rows_ignored():
    pts, ctr, w = _kmeans_case(1024, 16, 8, seed=7, pad_frac=0.25)
    sums, counts, inertia = kmeans.kmeans_step(pts, ctr, w, tile_n=256)
    assert float(jnp.sum(counts)) == pytest.approx(768.0)
    assert np.isfinite(float(inertia))
    assert float(inertia) < 1e8  # poisoned 1e6 rows would explode this

def test_kmeans_counts_conserve_weight():
    pts, ctr, w = _kmeans_case(512, 4, 4, seed=3)
    w = jnp.asarray(RNG(3).uniform(0, 2, size=512).astype(np.float32))
    sums, counts, _ = kmeans.kmeans_step(pts, ctr, w, tile_n=128)
    assert_allclose(float(jnp.sum(counts)), float(jnp.sum(w)), rtol=1e-5)


def test_kmeans_single_cluster_sums_everything():
    pts, _, w = _kmeans_case(256, 4, 1, seed=11)
    ctr = jnp.zeros((1, 4), jnp.float32)
    sums, counts, _ = kmeans.kmeans_step(pts, ctr, w, tile_n=128)
    assert_allclose(np.asarray(sums[0]), np.asarray(jnp.sum(pts, axis=0)),
                    rtol=1e-4, atol=1e-4)
    assert float(counts[0]) == 256.0


def test_kmeans_rejects_ragged():
    pts, ctr, w = _kmeans_case(500, 4, 2, seed=1)
    with pytest.raises(ValueError):
        kmeans.kmeans_step(pts, ctr, w, tile_n=256)


@settings(max_examples=25, deadline=None)
@given(
    n_tiles=st.integers(1, 6),
    tile=st.sampled_from([128, 256, 512]),
    d=st.sampled_from([2, 4, 8, 16]),
    k=st.sampled_from([1, 2, 5, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
    pad=st.sampled_from([0.0, 0.1, 0.5]),
)
def test_kmeans_hypothesis_sweep(n_tiles, tile, d, k, seed, pad):
    n = n_tiles * tile
    pts, ctr, w = _kmeans_case(n, d, k, seed, pad_frac=pad)
    got = kmeans.kmeans_step(pts, ctr, w, tile_n=tile)
    want = ref.kmeans_step_ref(pts, ctr, w)
    for g, r in zip(got, want):
        assert_allclose(np.asarray(g), np.asarray(r), rtol=3e-5, atol=3e-5)


# ------------------------------------------------------------- split scan

def _split_case(n, c, seed, n_valid=None, sorted_labels=True):
    rng = RNG(seed)
    n_valid = n if n_valid is None else n_valid
    ids = rng.integers(0, c, size=n_valid)
    if sorted_labels:
        # A feature-sorted stream: labels correlate with position, which is
        # what gives a nontrivial best split.
        ids = np.sort(ids)
    ids = np.concatenate([ids, np.zeros(n - n_valid, np.int64)])
    valid = np.concatenate(
        [np.ones(n_valid, np.float32), np.zeros(n - n_valid, np.float32)]
    )
    onehot = np.zeros((n, c), np.float32)
    onehot[np.arange(n_valid), ids[:n_valid]] = 1.0
    return jnp.asarray(onehot), jnp.asarray(valid)


@pytest.mark.parametrize("n,c,tile", [
    (2048, 8, 2048),
    (4096, 8, 2048),
    (4096, 2, 1024),
    (8192, 4, 2048),
])
def test_split_matches_ref(n, c, tile):
    oh, valid = _split_case(n, c, seed=n + c)
    g_got, i_got = split_scan.split_scan(oh, valid, tile=tile)
    g_want, i_want = ref.split_scan_ref(oh, valid)
    assert_allclose(float(g_got), float(g_want), rtol=1e-4, atol=1e-5)
    # Positions may differ only between equal-gain ties.
    if int(i_got) != int(i_want):
        gains = _bruteforce_gains(np.asarray(oh), np.asarray(valid))
        assert_allclose(gains[int(i_got)], gains[int(i_want)], atol=1e-5)


def _bruteforce_gains(onehot, valid):
    """O(n*c) numpy reimplementation used as a second, independent oracle."""
    n = onehot.shape[0]
    total = onehot.sum(axis=0)
    n_tot = valid.sum()

    def H(h):
        s = h.sum()
        if s <= 0:
            return 0.0
        p = h / s
        p = p[p > 0]
        return float(-(p * np.log2(p)).sum())

    parent = H(total)
    gains = np.full(n, -np.inf)
    left = np.zeros_like(total)
    n_l = 0.0
    for i in range(n):
        left = left + onehot[i]
        n_l += valid[i]
        n_r = n_tot - n_l
        if valid[i] > 0 and n_r > 0:
            gains[i] = parent - (n_l * H(left) + n_r * H(total - left)) / n_tot
    return gains


def test_split_perfectly_separable():
    # 0s then 1s: the boundary split has gain == parent entropy (1 bit).
    n, c = 2048, 2
    ids = np.concatenate([np.zeros(n // 2, int), np.ones(n // 2, int)])
    onehot = np.eye(c, dtype=np.float32)[ids]
    valid = np.ones(n, np.float32)
    gain, idx = split_scan.split_scan(
        jnp.asarray(onehot), jnp.asarray(valid), tile=1024
    )
    assert_allclose(float(gain), 1.0, atol=1e-5)
    assert int(idx) == n // 2 - 1


def test_split_pure_stream_no_gain():
    n, c = 2048, 4
    onehot = np.zeros((n, c), np.float32)
    onehot[:, 2] = 1.0
    valid = np.ones(n, np.float32)
    gain, _ = split_scan.split_scan(jnp.asarray(onehot), jnp.asarray(valid))
    assert float(gain) == pytest.approx(0.0, abs=1e-5)


def test_split_with_padding_tail():
    oh, valid = _split_case(4096, 4, seed=5, n_valid=3000)
    g_got, i_got = split_scan.split_scan(oh, valid, tile=1024)
    g_want, _ = ref.split_scan_ref(oh, valid)
    assert_allclose(float(g_got), float(g_want), rtol=1e-4, atol=1e-5)
    assert int(i_got) < 3000  # never split inside the padding


@settings(max_examples=20, deadline=None)
@given(
    blocks=st.integers(1, 5),
    tile=st.sampled_from([512, 1024, 2048]),
    c=st.sampled_from([2, 3, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
    frac=st.floats(0.3, 1.0),
    sorted_labels=st.booleans(),
)
def test_split_hypothesis_sweep(blocks, tile, c, seed, frac, sorted_labels):
    n = blocks * tile
    n_valid = max(2, int(n * frac))
    oh, valid = _split_case(n, c, seed, n_valid, sorted_labels)
    g_got, _ = split_scan.split_scan(oh, valid, tile=tile)
    g_want, _ = ref.split_scan_ref(oh, valid)
    got, want = float(g_got), float(g_want)
    if not (np.isinf(want) and np.isinf(got)):
        assert_allclose(got, want, rtol=2e-4, atol=1e-5)


# ----------------------------------------------------- delta / score refs

def test_delta_stat_identical_windows_is_zero():
    rng = RNG(0)
    c = jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))
    live = jnp.ones(8, jnp.float32)
    assert float(ref.delta_stat_ref(c, c, live, live)) == pytest.approx(0.0)


def test_delta_stat_translation():
    rng = RNG(1)
    a = jnp.asarray(rng.normal(size=(4, 3)).astype(np.float32))
    b = a + 2.0  # nearest-neighbour distance <= 12 = ||(2,2,2)||^2 each
    live = jnp.ones(4, jnp.float32)
    d = float(ref.delta_stat_ref(a, b, live, live))
    assert 0.0 < d <= 4 * 12.0 + 1e-4


def test_delta_stat_dead_centers_ignored():
    a = jnp.asarray(np.zeros((4, 2), np.float32))
    b = jnp.asarray(np.full((4, 2), 100.0, np.float32))
    b = b.at[0].set(0.0)
    live_a = jnp.asarray([1.0, 0, 0, 0], jnp.float32)
    live_b = jnp.ones(4, jnp.float32)
    # only a[0] counts; nearest live b center is b[0] at distance 0
    assert float(ref.delta_stat_ref(a, b, live_a, live_b)) == pytest.approx(0.0)


def test_score_peak_at_center():
    ctr = jnp.asarray(np.zeros((2, 3), np.float32))
    x = jnp.asarray(np.zeros((1, 3), np.float32))
    s2 = jnp.ones(2, jnp.float32)
    th = jnp.asarray([0.7, 0.3], jnp.float32)
    lam = jnp.ones(2, jnp.float32)
    live = jnp.ones(2, jnp.float32)
    r = ref.score_ref(x, ctr, s2, th, lam, live)
    assert float(r[0]) == pytest.approx(0.7)  # max_k theta_k at distance 0


def test_score_decays_with_distance():
    ctr = jnp.asarray(np.zeros((1, 2), np.float32))
    xs = jnp.asarray(np.array([[0, 0], [1, 0], [3, 0]], np.float32))
    one = jnp.ones(1, jnp.float32)
    r = np.asarray(ref.score_ref(xs, ctr, one, one, one, one))
    assert r[0] > r[1] > r[2] > 0

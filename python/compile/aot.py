"""AOT lowering: JAX (L2, with L1 Pallas kernels inside) -> HLO text.

HLO *text* -- not `lowered.compile()` nor a serialized HloModuleProto --
is the interchange format: jax >= 0.5 emits protos with 64-bit instruction
ids which the `xla` crate's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser on the Rust side reassigns ids
and round-trips cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
Writes one `<name>.hlo.txt` per entry in model.ARTIFACTS plus a manifest.
"""
from __future__ import annotations

import argparse
import hashlib
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, example_args) in model.ARTIFACTS.items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest[name] = (len(text), digest)
        print(f"  {name:<12} {len(text):>8} chars  sha256:{digest}  -> {path}")
    # Manifest lets `make` (and the Rust runtime) detect staleness cheaply.
    with open(os.path.join(out_dir, "MANIFEST.txt"), "w") as f:
        for name, (size, digest) in sorted(manifest.items()):
            f.write(f"{name} {size} {digest}\n")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    print(f"lowering {len(model.ARTIFACTS)} artifacts -> {args.out_dir}")
    lower_all(args.out_dir)
    print("done")


if __name__ == "__main__":
    main()

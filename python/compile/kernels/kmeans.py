"""L1 Pallas kernel: one weighted Lloyd's k-means assignment+accumulation step.

Hardware adaptation (DESIGN.md section "Hardware-Adaptation"): on a GPU one
would give each threadblock a chunk of points, keep the centroid table in
shared memory, and scatter-add partial sums with atomics.  TPUs have neither
fast scatter nor atomics, so the kernel is restructured around the MXU:

  * the distance term is the matmul  X_tile (TILE_N, d)  @  C^T (d, k)
    -- the dominant FLOPs land on the systolic array;
  * the per-cluster accumulation is the matmul  onehot^T (k, TILE_N) @ X_tile
    (TILE_N, d) -- scatter-add re-expressed as a second MXU contraction;
  * the grid walks the N axis sequentially; accumulators (sums, counts,
    inertia) live in the *output* VMEM blocks whose index_map pins them to
    block (0, 0) for every grid step -- the canonical Pallas reduction
    carry.  Grid-step 0 zero-initialises them.

VMEM budget per grid step (f32, defaults TILE_N=512, d=16, k=32):
  X tile 512*16*4 = 32 KiB, centers 32*16*4 = 2 KiB, distances
  512*32*4 = 64 KiB, onehot 64 KiB, outputs ~2.3 KiB  ==>  ~165 KiB,
  comfortably inside a 16 MiB VMEM even at TILE_N=8192.  MXU utilisation
  estimate in DESIGN.md (section "Hardware-Adaptation").

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the AOT artifact runs
in the Rust runtime.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile along the point axis.  Must divide the (padded) n.
TILE_N = 512


def _kmeans_kernel(x_ref, c_ref, w_ref, sums_ref, counts_ref, inertia_ref):
    """One grid step: TILE_N points against the full (k, d) center table."""
    step = pl.program_id(0)

    x = x_ref[...]                       # (TILE_N, d)
    c = c_ref[...]                       # (k, d)
    w = w_ref[...]                       # (TILE_N,)

    # Zero the carried accumulators on the first step.
    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        inertia_ref[...] = jnp.zeros_like(inertia_ref)

    # Squared distances via the MXU-friendly expansion.
    x2 = jnp.sum(x * x, axis=1, keepdims=True)                    # (T, 1)
    c2 = jnp.sum(c * c, axis=1)[None, :]                          # (1, k)
    xc = jnp.dot(x, c.T, preferred_element_type=jnp.float32)      # (T, k) MXU
    d2 = x2 - 2.0 * xc + c2                                       # (T, k)

    assign = jnp.argmin(d2, axis=1)                               # (T,)
    best = jnp.min(d2, axis=1)                                    # (T,)

    k = c.shape[0]
    onehot = jnp.asarray(
        assign[:, None] == jax.lax.broadcasted_iota(jnp.int32, (1, k), 1),
        dtype=x.dtype,
    ) * w[:, None]                                                # (T, k)

    # Scatter-add as a second MXU contraction: (k, T) @ (T, d).
    sums_ref[...] += jnp.dot(onehot.T, x, preferred_element_type=jnp.float32)
    counts_ref[...] += jnp.sum(onehot, axis=0)
    inertia_ref[...] += jnp.sum(jnp.maximum(best, 0.0) * w)[None]


@functools.partial(jax.jit, static_argnames=("tile_n",))
def kmeans_step(points, centers, weights, *, tile_n=TILE_N):
    """Pallas-tiled weighted Lloyd's step.  Semantics == ref.kmeans_step_ref.

    points (n, d) f32, centers (k, d) f32, weights (n,) f32 with n a
    multiple of tile_n (pad with weight-0 rows).  Returns (sums (k, d),
    counts (k,), inertia ()).
    """
    n, d = points.shape
    k, _ = centers.shape
    if n % tile_n != 0:
        raise ValueError(f"n={n} must be a multiple of tile_n={tile_n}")
    grid = (n // tile_n,)

    sums, counts, inertia = pl.pallas_call(
        _kmeans_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # stream X tiles
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # centers resident
            pl.BlockSpec((tile_n,), lambda i: (i,)),       # weight tiles
        ],
        out_specs=[
            pl.BlockSpec((k, d), lambda i: (0, 0)),        # carried accum
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, d), jnp.float32),
            jax.ShapeDtypeStruct((k,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(points, centers, weights)
    return sums, counts, inertia[0]

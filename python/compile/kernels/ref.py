"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness).

Every kernel in this package has an exact (up to float associativity)
counterpart here; pytest asserts allclose between the two across
hypothesis-driven shape/seed sweeps.  These are also the semantics the
Rust coordinator assumes when it invokes the AOT artifacts.
"""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_step_ref(points, centers, weights):
    """One weighted Lloyd's assignment+accumulation step.

    Args:
      points:  (n, d) f32
      centers: (k, d) f32
      weights: (n,)  f32 -- 1.0 for live rows, 0.0 for padding

    Returns:
      sums:    (k, d) f32 -- per-cluster weighted coordinate sums
      counts:  (k,)   f32 -- per-cluster weighted row counts
      inertia: ()     f32 -- weighted sum of squared distance to the
                             assigned (nearest) center
    """
    # ||x - c||^2 = ||x||^2 - 2 x.c + ||c||^2   (MXU-friendly form)
    x2 = jnp.sum(points * points, axis=1, keepdims=True)          # (n, 1)
    c2 = jnp.sum(centers * centers, axis=1)[None, :]              # (1, k)
    d2 = x2 - 2.0 * points @ centers.T + c2                       # (n, k)
    assign = jnp.argmin(d2, axis=1)                               # (n,)
    best = jnp.min(d2, axis=1)                                    # (n,)
    onehot = jnp.asarray(
        assign[:, None] == jnp.arange(centers.shape[0])[None, :],
        dtype=points.dtype,
    ) * weights[:, None]                                          # (n, k)
    sums = onehot.T @ points                                      # (k, d)
    counts = jnp.sum(onehot, axis=0)                              # (k,)
    inertia = jnp.sum(jnp.maximum(best, 0.0) * weights)
    return sums, counts, inertia


def split_scan_ref(labels_onehot, valid):
    """Best single split of a sorted label sequence by information gain.

    The sequence is assumed sorted by the (implicit) feature; a split at
    position i sends rows [0, i] left and (i, n) right.  Gain is parent
    entropy minus the size-weighted child entropies (base-2, as in CART
    with the entropy impurity).  Padding rows have valid == 0 and must sit
    at the tail.

    Args:
      labels_onehot: (n, c) f32 one-hot class labels (zero rows for padding)
      valid:         (n,)   f32 -- 1.0 live, 0.0 padding

    Returns:
      best_gain: () f32 -- maximum information gain over all splits
      best_idx:  () f32 -- split position achieving it (last row of the
                           left child), as f32 for artifact uniformity
    """
    eps = jnp.asarray(1e-12, labels_onehot.dtype)

    def entropy(h, n):
        p = h / jnp.maximum(n, eps)[..., None]
        return -jnp.sum(jnp.where(p > 0, p * jnp.log2(p + eps), 0.0), axis=-1)

    total = jnp.sum(labels_onehot, axis=0)                        # (c,)
    n_total = jnp.sum(valid)
    parent = entropy(total[None, :], n_total[None])[0]

    left = jnp.cumsum(labels_onehot, axis=0)                      # (n, c)
    n_left = jnp.cumsum(valid)                                    # (n,)
    right = total[None, :] - left
    n_right = n_total - n_left
    h_l = entropy(left, n_left)
    h_r = entropy(right, n_right)
    gain = parent - (n_left * h_l + n_right * h_r) / jnp.maximum(n_total, eps)
    # A split must leave at least one row on each side and be a live row.
    ok = (valid > 0) & (n_right > 0)
    gain = jnp.where(ok, gain, -jnp.inf)
    best_idx = jnp.argmax(gain)
    return gain[best_idx], best_idx.astype(labels_onehot.dtype)


def delta_stat_ref(centers_a, centers_b, live_a, live_b):
    """The paper's cluster-movement statistic (Section 7.1):

        delta_j = sum_n  min_m || a_{j,n} - a_{j+1,m} ||^2

    summed over live centers of window j, min over live centers of j+1.
    """
    d2 = jnp.sum((centers_a[:, None, :] - centers_b[None, :, :]) ** 2, axis=-1)
    big = jnp.asarray(3.0e38, centers_a.dtype)
    d2 = jnp.where(live_b[None, :] > 0, d2, big)
    mins = jnp.min(d2, axis=1)
    return jnp.sum(jnp.where(live_a > 0, mins, 0.0))


def score_ref(x, centers, sigma2, theta, lam, live):
    """The paper's emergent-behaviour score (Section 7.1):

        rho_k(x) = theta_k * exp(-lam_k^2 ||x - a_k||^2 / (2 sigma_k^2))
        rho(x)   = max_k rho_k(x)        (over live emergent clusters k)
    """
    d2 = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=-1)  # (n,k)
    z = -(lam[None, :] ** 2) * d2 / (2.0 * jnp.maximum(sigma2, 1e-12)[None, :])
    rho_k = theta[None, :] * jnp.exp(z)
    rho_k = jnp.where(live[None, :] > 0, rho_k, 0.0)
    return jnp.max(rho_k, axis=1)

"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts)."""
from .kmeans import kmeans_step
from .split_scan import split_scan

__all__ = ["kmeans_step", "split_scan"]

"""L1 Pallas kernel: blocked entropy-gain scan for Terasplit.

Terasplit (paper section 6.2) computes the single best CART split of a
label sequence that Terasort has already ordered by key.  The scan is a
running class histogram: at split position i the left child holds the
prefix counts, the right child the complement, and the information gain is

    gain(i) = H(total) - (n_l * H(left) + n_r * H(right)) / n.

Hardware adaptation: a GPU version would do a device-wide prefix sum
(decoupled-lookback) across threadblocks.  TPUs run the Pallas grid
*sequentially*, so the cross-block carry is free: the running histogram is
an output block pinned to (0, 0) that each grid step reads, extends with
an in-block cumsum, and writes back.  The per-position entropy evaluation
is fully vectorised on the VPU (8x128 lanes); there is no MXU work --
this kernel is bandwidth-bound, and the roofline discussion in
DESIGN.md (section "Hardware-Adaptation") treats it as such.

The kernel needs the *total* histogram before the scan starts; the L2
wrapper computes it with one cheap jnp reduction and passes it in, keeping
the kernel single-pass.

VMEM per grid step (TILE=2048, c=8, f32): labels 64 KiB, prefix/right
64 KiB each, gains 8 KiB, carry c*4 B  ==> ~210 KiB.

interpret=True: see kernels/kmeans.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 2048
NEG = -3.0e38  # sentinel for masked gains (finite to keep HLO max simple)


def _entropy(h, n, eps):
    p = h / jnp.maximum(n, eps)[..., None]
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(p + eps), 0.0), axis=-1)


def _split_kernel(lab_ref, val_ref, tot_ref, ntot_ref,
                  gain_ref, idx_ref, hcarry_ref, ncarry_ref):
    """One grid step: TILE one-hot label rows; emits per-block best gain."""
    step = pl.program_id(0)
    eps = jnp.float32(1e-12)

    lab = lab_ref[...]                   # (TILE, c) one-hot f32
    val = val_ref[...]                   # (TILE,)
    total = tot_ref[...]                 # (c,)
    n_total = ntot_ref[0]                # ()

    @pl.when(step == 0)
    def _init():
        hcarry_ref[...] = jnp.zeros_like(hcarry_ref)
        ncarry_ref[...] = jnp.zeros_like(ncarry_ref)
        gain_ref[...] = jnp.full_like(gain_ref, NEG)
        idx_ref[...] = jnp.zeros_like(idx_ref)

    h0 = hcarry_ref[...]                 # (c,) histogram before this block
    n0 = ncarry_ref[0]                   # ()

    left = h0[None, :] + jnp.cumsum(lab, axis=0)      # (TILE, c)
    n_left = n0 + jnp.cumsum(val)                     # (TILE,)
    right = total[None, :] - left
    n_right = n_total - n_left

    parent = _entropy(total[None, :], n_total[None], eps)[0]
    h_l = _entropy(left, n_left, eps)
    h_r = _entropy(right, n_right, eps)
    gain = parent - (n_left * h_l + n_right * h_r) / jnp.maximum(n_total, eps)
    ok = (val > 0) & (n_right > 0)
    gain = jnp.where(ok, gain, NEG)

    tile = lab.shape[0]
    local = jnp.argmax(gain)
    best = gain[local]

    # Keep the running (gain, idx) argmax across blocks in the carried
    # outputs; positions are global row indices.
    prev = gain_ref[0]
    take = best > prev
    gain_ref[...] = jnp.where(take, best, prev)[None]
    idx_ref[...] = jnp.where(
        take, jnp.float32(step * tile) + local.astype(jnp.float32), idx_ref[0]
    )[None]

    hcarry_ref[...] = left[tile - 1, :]
    ncarry_ref[...] = n_left[tile - 1][None]


@functools.partial(jax.jit, static_argnames=("tile",))
def split_scan(labels_onehot, valid, *, tile=TILE):
    """Pallas blocked split scan.  Semantics == ref.split_scan_ref.

    labels_onehot (n, c) f32 one-hot rows (zeros for padding), valid (n,)
    f32, n a multiple of `tile` and padding confined to the tail.
    Returns (best_gain (), best_idx () as f32).
    """
    n, c = labels_onehot.shape
    if n % tile != 0:
        raise ValueError(f"n={n} must be a multiple of tile={tile}")
    grid = (n // tile,)

    total = jnp.sum(labels_onehot, axis=0)            # (c,) cheap L2 pre-pass
    n_total = jnp.sum(valid)[None]                    # (1,)

    gain, idx, _hc, _nc = pl.pallas_call(
        _split_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((c,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),       # best gain (carried)
            pl.BlockSpec((1,), lambda i: (0,)),       # best idx  (carried)
            pl.BlockSpec((c,), lambda i: (0,)),       # histogram carry
            pl.BlockSpec((1,), lambda i: (0,)),       # count carry
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
            jax.ShapeDtypeStruct((c,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,
    )(labels_onehot, valid, total, n_total)
    return gain[0], idx[0]

"""L2: the JAX compute graphs the Rust coordinator invokes via PJRT.

Each public function here is lowered once by aot.py to an HLO-text
artifact with *fixed* shapes (the AOT contract below); the Rust runtime
pads its inputs to those shapes.  The hot functions call the L1 Pallas
kernels so the kernels lower into the same HLO module.

AOT contract (all f32):

  kmeans_step : points (N, D), centers (K, D), weights (N,)
                -> (sums (K, D), counts (K,), inertia ())
  split_gain  : labels (N2,) int32-as-f32 class ids in [0, C), valid (N2,)
                -> (best_gain (), best_idx ())
  delta_stat  : centers_a (K, D), centers_b (K, D), live_a (K,), live_b (K,)
                -> (delta ())
  score       : x (B, D), centers (K, D), sigma2 (K,), theta (K,), lam (K,),
                live (K,) -> (rho (B,))

with N = 4096, D = 16, K = 32, N2 = 32768, C = 8, B = 256
(runtime constants mirrored in rust/src/runtime/artifact.rs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import kmeans_step as _kmeans_kernel
from .kernels import split_scan as _split_kernel

# The artifact shapes.  Keep in sync with rust/src/runtime/artifact.rs.
N_POINTS = 4096
N_DIM = 16
N_CLUSTERS = 32
N_LABELS = 32768
N_CLASSES = 8
N_SCORE_BATCH = 256


def kmeans_step(points, centers, weights):
    """One Lloyd's step over a padded point block (L1 kernel inside)."""
    return _kmeans_kernel(points, centers, weights)


def split_gain(class_ids, valid):
    """Terasplit: best entropy split of a key-sorted label sequence.

    class_ids are integer class labels carried as f32 (PJRT artifact
    uniformity); they are one-hot encoded here so the kernel sees the
    (N2, C) layout it tiles over.
    """
    ids = class_ids.astype(jnp.int32)
    onehot = jnp.asarray(
        ids[:, None] == jnp.arange(N_CLASSES)[None, :], dtype=jnp.float32
    ) * valid[:, None]
    return _split_kernel(onehot, valid)


def delta_stat(centers_a, centers_b, live_a, live_b):
    """Cluster-movement statistic delta_j (paper section 7.1).

    Small (K x K) problem: pure L2, no kernel -- XLA fuses the whole
    thing into a couple of loops; a Pallas kernel would only add
    dispatch overhead.
    """
    d2 = jnp.sum((centers_a[:, None, :] - centers_b[None, :, :]) ** 2, axis=-1)
    big = jnp.asarray(3.0e38, jnp.float32)
    d2 = jnp.where(live_b[None, :] > 0, d2, big)
    mins = jnp.min(d2, axis=1)
    return jnp.sum(jnp.where(live_a > 0, mins, 0.0))


def score(x, centers, sigma2, theta, lam, live):
    """Emergent-behaviour score rho(x) = max_k rho_k(x) (paper 7.1)."""
    d2 = jnp.sum((x[:, None, :] - centers[None, :, :]) ** 2, axis=-1)
    z = -(lam[None, :] ** 2) * d2 / (2.0 * jnp.maximum(sigma2, 1e-12)[None, :])
    rho_k = theta[None, :] * jnp.exp(z)
    rho_k = jnp.where(live[None, :] > 0, rho_k, 0.0)
    return jnp.max(rho_k, axis=1)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# name -> (fn, example_args); consumed by aot.py.
ARTIFACTS = {
    "kmeans_step": (
        kmeans_step,
        (_spec(N_POINTS, N_DIM), _spec(N_CLUSTERS, N_DIM), _spec(N_POINTS)),
    ),
    "split_gain": (
        split_gain,
        (_spec(N_LABELS), _spec(N_LABELS)),
    ),
    "delta_stat": (
        delta_stat,
        (
            _spec(N_CLUSTERS, N_DIM),
            _spec(N_CLUSTERS, N_DIM),
            _spec(N_CLUSTERS),
            _spec(N_CLUSTERS),
        ),
    ),
    "score": (
        score,
        (
            _spec(N_SCORE_BATCH, N_DIM),
            _spec(N_CLUSTERS, N_DIM),
            _spec(N_CLUSTERS),
            _spec(N_CLUSTERS),
            _spec(N_CLUSTERS),
            _spec(N_CLUSTERS),
        ),
    ),
}
